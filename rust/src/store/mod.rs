//! Crash- and corruption-safe storage: the state-layer twin of `net/`.
//!
//! PR 9 made the *wire* fault-tolerant; this module does the same for
//! the *disk*. It provides an injectable [`Store`] abstraction with a
//! real filesystem backend ([`FsStore`] — tmp write, file fsync, atomic
//! rename, **parent-directory fsync**: rename alone is not durable on
//! ext4/xfs) and a scripted fault injector ([`FaultStore`], the disk
//! twin of `FaultInjectTransport`), plus the checksummed sealed frame
//! ([`seal`]/[`unseal`], CRC32 over the payload) and the generational
//! checkpoint layout ([`CheckpointStore`]: `base.NNNNN`, keep-K with
//! pruning, newest→oldest recovery to the last generation that passes
//! magic+checksum+decode).
//!
//! The paper's Theorem 1 tolerance for slightly-outdated models is what
//! makes generation fallback *semantically* safe: resuming one
//! checkpoint older than the corrupted head is just a bounded-staleness
//! restart, not a correctness loss.

pub mod fault;
pub mod generations;

pub use fault::{FaultStore, IoError, IoFaultKind, IoFaultPlan};
pub use generations::CheckpointStore;

use crate::net::wire::{put_len, put_u32, Reader};
use anyhow::{ensure, Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

/// An injectable blob store: flat namespace of named byte blobs. The
/// real backend is [`FsStore`]; tests and chaos drills wrap it in a
/// [`FaultStore`]. `put` is required to be *atomic and durable*: after
/// it returns `Ok`, the full blob is readable under `name` even across
/// a power loss; after an `Err`, the previous blob under `name` (if
/// any) may be gone only if the backend explicitly tore it.
pub trait Store: Send {
    /// Atomically publish `bytes` under `name`.
    fn put(&mut self, name: &str, bytes: &[u8]) -> Result<()>;
    /// Read back the full blob stored under `name`.
    fn get(&self, name: &str) -> Result<Vec<u8>>;
    /// All blob names currently in the store, in no particular order.
    fn list(&self) -> Result<Vec<String>>;
    /// Remove `name`; removing a missing blob is not an error.
    fn remove(&mut self, name: &str) -> Result<()>;
}

/// Real-filesystem backend rooted at one directory. Writes follow the
/// full durability protocol: `name.tmp` → `write_all` → `sync_all` →
/// `rename(name.tmp, name)` → fsync the directory (the rename is only
/// durable once the directory entry itself reaches the disk). The tmp
/// suffix is *appended* to the name, never substituted for an
/// extension, so generation files like `ckpt.00003` get distinct tmp
/// names instead of colliding on `ckpt.tmp`.
pub struct FsStore {
    dir: PathBuf,
}

impl FsStore {
    pub fn open(dir: &Path) -> Result<Self> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("store: create directory {}", dir.display()))?;
        Ok(FsStore { dir: dir.to_path_buf() })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }

    fn sync_dir(&self) -> Result<()> {
        let d = std::fs::File::open(&self.dir)
            .with_context(|| format!("store: open directory {} for fsync", self.dir.display()))?;
        d.sync_all()
            .with_context(|| format!("store: fsync directory {}", self.dir.display()))
    }
}

impl Store for FsStore {
    fn put(&mut self, name: &str, bytes: &[u8]) -> Result<()> {
        let tmp = self.path(&format!("{name}.tmp"));
        let dst = self.path(name);
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("store: create {}", tmp.display()))?;
        f.write_all(bytes).with_context(|| format!("store: write {}", tmp.display()))?;
        f.sync_all().with_context(|| format!("store: fsync {}", tmp.display()))?;
        drop(f);
        std::fs::rename(&tmp, &dst)
            .with_context(|| format!("store: rename {} -> {}", tmp.display(), dst.display()))?;
        self.sync_dir()
    }

    fn get(&self, name: &str) -> Result<Vec<u8>> {
        let p = self.path(name);
        std::fs::read(&p).with_context(|| format!("store: read {}", p.display()))
    }

    fn list(&self) -> Result<Vec<String>> {
        let mut names = Vec::new();
        let entries = std::fs::read_dir(&self.dir)
            .with_context(|| format!("store: list {}", self.dir.display()))?;
        for entry in entries {
            let entry = entry.with_context(|| format!("store: list {}", self.dir.display()))?;
            if entry.file_type().map(|t| t.is_file()).unwrap_or(false) {
                if let Some(name) = entry.file_name().to_str() {
                    names.push(name.to_string());
                }
            }
        }
        Ok(names)
    }

    fn remove(&mut self, name: &str) -> Result<()> {
        let p = self.path(name);
        match std::fs::remove_file(&p) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e).with_context(|| format!("store: remove {}", p.display())),
        }
    }
}

// --- Checksummed sealed frame -------------------------------------------

const FRAME_MAGIC: u32 = 0x50_41_53_47; // "PASG": para-active sealed generation
const FRAME_VERSION: u32 = 1;

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC32_TABLE: [u32; 256] = crc32_table();

/// CRC32 (IEEE 802.3, reflected) — table generated at compile time; no
/// dependency footprint, fast enough for checkpoint-sized payloads.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = !0u32;
    for &b in bytes {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// Wrap a payload in the sealed frame: magic, version, CRC32 of the
/// payload, payload length, payload bytes.
pub fn seal(payload: &[u8]) -> Result<Vec<u8>> {
    let mut buf = Vec::with_capacity(payload.len() + 16);
    put_u32(&mut buf, FRAME_MAGIC);
    put_u32(&mut buf, FRAME_VERSION);
    put_u32(&mut buf, crc32(payload));
    put_len(&mut buf, payload.len()).context("sealed frame: payload length")?;
    buf.extend_from_slice(payload);
    Ok(buf)
}

/// Verify and strip the sealed frame; every failure (bad magic, wrong
/// version, length mismatch, checksum mismatch) is a typed decode
/// error, never a panic. The declared length is cross-checked against
/// the bytes actually present *before* the payload is copied, so a
/// corrupt header can never request an OOM-sized allocation.
pub fn unseal(bytes: &[u8]) -> Result<Vec<u8>> {
    let mut r = Reader::new(bytes);
    let magic = r.u32().context("sealed frame: magic")?;
    ensure!(magic == FRAME_MAGIC, "sealed frame: bad magic {magic:#010x}");
    let version = r.u32().context("sealed frame: version")?;
    ensure!(version == FRAME_VERSION, "sealed frame: unsupported version {version}");
    let want = r.u32().context("sealed frame: checksum")?;
    let n = r.u32().context("sealed frame: payload length")? as usize;
    ensure!(
        r.remaining() == n,
        "sealed frame: payload length {n} but {} byte(s) present",
        r.remaining()
    );
    let payload = r.bytes(n).context("sealed frame: payload")?;
    let got = crc32(&payload);
    ensure!(
        got == want,
        "sealed frame: checksum mismatch (stored {want:#010x}, computed {got:#010x})"
    );
    Ok(payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("para-active-store-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // The classic IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn seal_unseal_roundtrips_and_rejects_corruption() {
        let payload = b"para-active checkpoint payload".to_vec();
        let sealed = seal(&payload).unwrap();
        assert_eq!(unseal(&sealed).unwrap(), payload);

        // Every prefix truncation is a typed error.
        for cut in 0..sealed.len() {
            assert!(unseal(&sealed[..cut]).is_err(), "truncation at {cut} must fail");
        }
        // Every single-byte flip is detected (magic, version, length, or CRC).
        for i in 0..sealed.len() {
            let mut m = sealed.clone();
            m[i] ^= 0x01;
            assert!(unseal(&m).is_err(), "flip at byte {i} must fail");
        }
        // Trailing garbage is rejected too.
        let mut long = sealed.clone();
        long.push(0);
        assert!(unseal(&long).is_err());
    }

    #[test]
    fn fs_store_puts_atomically_and_lists_files() {
        let dir = temp_dir("fs");
        let mut s = FsStore::open(&dir).unwrap();
        s.put("a", b"alpha").unwrap();
        s.put("b", b"beta").unwrap();
        s.put("a", b"alpha-2").unwrap(); // overwrite goes through the same protocol
        assert_eq!(s.get("a").unwrap(), b"alpha-2");
        assert_eq!(s.get("b").unwrap(), b"beta");
        let mut names = s.list().unwrap();
        names.sort();
        assert_eq!(names, vec!["a".to_string(), "b".to_string()], "no tmp residue");
        s.remove("a").unwrap();
        s.remove("a").unwrap(); // idempotent
        assert!(s.get("a").is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
