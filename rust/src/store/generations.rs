//! Generation-rotated checkpoints over any [`Store`].
//!
//! Layout: `base.00001`, `base.00002`, … — every save publishes a *new*
//! sealed generation (never overwriting the last good one), then prunes
//! down to the newest `keep`. Recovery scans newest→oldest and returns
//! the first generation whose sealed frame verifies (magic + CRC32 +
//! length) *and* whose payload decodes; everything skipped on the way
//! is counted in `recovery.corrupt_generations_skipped`. With saves at
//! every segment boundary, falling back one generation costs exactly
//! one re-run segment — the bounded staleness Theorem 1 already prices
//! in.

use super::{seal, unseal, FsStore, Store};
use anyhow::{bail, Context, Result};
use std::path::Path;

pub struct CheckpointStore {
    store: Box<dyn Store>,
    base: String,
    keep: usize,
    next_gen: u64,
    skipped: u64,
}

impl CheckpointStore {
    /// Open (creating the directory if needed) a generation store for
    /// the session rooted at `path`: generations live beside it as
    /// `path.NNNNN`. Stray `*.tmp` files from interrupted writes are
    /// cleaned up here, on session open.
    pub fn open(path: &Path, keep: usize) -> Result<CheckpointStore> {
        let parent = match path.parent() {
            Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
            _ => std::path::PathBuf::from("."),
        };
        let base = path
            .file_name()
            .and_then(|n| n.to_str())
            .with_context(|| format!("checkpoint store: bad session path {}", path.display()))?
            .to_string();
        let fs = FsStore::open(&parent)?;
        CheckpointStore::with_store(Box::new(fs), &base, keep)
    }

    /// Same, over an injected backend (tests and `--io-chaos` wrap the
    /// real store in a `FaultStore` here).
    pub fn with_store(store: Box<dyn Store>, base: &str, keep: usize) -> Result<CheckpointStore> {
        let mut cs = CheckpointStore {
            store,
            base: base.to_string(),
            keep: keep.max(1),
            next_gen: 1,
            skipped: 0,
        };
        cs.clean_stray_tmp()?;
        let gens = cs.generations()?;
        cs.next_gen = gens.last().map(|g| g + 1).unwrap_or(1);
        Ok(cs)
    }

    pub fn base(&self) -> &str {
        &self.base
    }

    pub fn keep(&self) -> usize {
        self.keep
    }

    /// Corrupt generations skipped by the most recent recovery scan.
    pub fn skipped(&self) -> u64 {
        self.skipped
    }

    fn gen_name(&self, generation: u64) -> String {
        format!("{}.{:05}", self.base, generation)
    }

    fn parse_gen(&self, name: &str) -> Option<u64> {
        let digits = name.strip_prefix(&self.base)?.strip_prefix('.')?;
        if digits.len() < 5 || !digits.bytes().all(|b| b.is_ascii_digit()) {
            return None;
        }
        digits.parse().ok()
    }

    fn clean_stray_tmp(&mut self) -> Result<()> {
        let stray: Vec<String> = self
            .store
            .list()?
            .into_iter()
            .filter(|n| n.starts_with(&self.base) && n.ends_with(".tmp"))
            .collect();
        for name in stray {
            self.store.remove(&name)?;
        }
        Ok(())
    }

    /// All generation numbers currently on disk, oldest first.
    pub fn generations(&self) -> Result<Vec<u64>> {
        let mut gens: Vec<u64> =
            self.store.list()?.iter().filter_map(|n| self.parse_gen(n)).collect();
        gens.sort_unstable();
        Ok(gens)
    }

    pub fn latest_generation(&self) -> Result<Option<u64>> {
        Ok(self.generations()?.last().copied())
    }

    /// Seal `payload` and publish it as the next generation, then prune
    /// down to `keep`. The generation counter advances even when the
    /// write fails, so a torn generation is never overwritten in place
    /// by the next save.
    pub fn save(&mut self, payload: &[u8]) -> Result<u64> {
        let generation = self.next_gen;
        self.next_gen += 1;
        let sealed = seal(payload)?;
        self.store
            .put(&self.gen_name(generation), &sealed)
            .with_context(|| format!("checkpoint store: saving generation {generation}"))?;
        let gens = self.generations()?;
        if gens.len() > self.keep {
            for &old in &gens[..gens.len() - self.keep] {
                self.store.remove(&self.gen_name(old))?;
            }
        }
        Ok(generation)
    }

    /// Recover the newest generation whose frame verifies and whose
    /// payload `decode`s, scanning newest→oldest. Returns `None` when
    /// no generations exist at all; errors when generations exist but
    /// every one is corrupt (silently starting fresh would lose data).
    pub fn load_latest_with<T>(
        &mut self,
        mut decode: impl FnMut(&[u8]) -> Result<T>,
    ) -> Result<Option<(u64, T)>> {
        let gens = self.generations()?;
        self.skipped = 0;
        for &generation in gens.iter().rev() {
            let verified = self
                .store
                .get(&self.gen_name(generation))
                .and_then(|bytes| unseal(&bytes))
                .and_then(|payload| decode(&payload));
            match verified {
                Ok(value) => return Ok(Some((generation, value))),
                Err(_) => {
                    self.skipped += 1;
                    crate::obs::counter("recovery.corrupt_generations_skipped").add(1);
                }
            }
        }
        if gens.is_empty() {
            Ok(None)
        } else {
            bail!(
                "checkpoint store: all {} generation(s) of {:?} are corrupt",
                gens.len(),
                self.base
            )
        }
    }

    /// Recover the newest frame-valid generation's raw payload.
    pub fn load_latest(&mut self) -> Result<Option<(u64, Vec<u8>)>> {
        self.load_latest_with(|payload| Ok(payload.to_vec()))
    }

    /// Remove every generation and stray tmp file (CLI `--fresh`).
    pub fn reset(&mut self) -> Result<()> {
        for generation in self.generations()? {
            self.store.remove(&self.gen_name(generation))?;
        }
        self.clean_stray_tmp()?;
        self.next_gen = 1;
        self.skipped = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::{FaultStore, FsStore, IoFaultPlan};
    use super::*;
    use std::path::PathBuf;

    fn temp_base(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("para-active-gens-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir.join("sess.ckpt")
    }

    #[test]
    fn generations_rotate_and_prune_to_keep() {
        let base = temp_base("rotate");
        let mut cs = CheckpointStore::open(&base, 3).unwrap();
        for i in 0..6u64 {
            let generation = cs.save(format!("payload-{i}").as_bytes()).unwrap();
            assert_eq!(generation, i + 1);
        }
        assert_eq!(cs.generations().unwrap(), vec![4, 5, 6], "keep-3 prunes the oldest");
        let (generation, payload) = cs.load_latest().unwrap().unwrap();
        assert_eq!(generation, 6);
        assert_eq!(payload, b"payload-5");
        std::fs::remove_dir_all(base.parent().unwrap()).unwrap();
    }

    #[test]
    fn recovery_skips_corrupt_generations_newest_to_oldest() {
        let base = temp_base("skip");
        let mut cs = CheckpointStore::open(&base, 4).unwrap();
        for i in 0..3u64 {
            cs.save(format!("payload-{i}").as_bytes()).unwrap();
        }
        // Corrupt the newest generation on disk behind the store's back.
        let newest = base.parent().unwrap().join("sess.ckpt.00003");
        let mut bytes = std::fs::read(&newest).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        std::fs::write(&newest, &bytes).unwrap();

        let (generation, payload) = cs.load_latest().unwrap().unwrap();
        assert_eq!(generation, 2, "falls back exactly one generation");
        assert_eq!(payload, b"payload-1");
        assert_eq!(cs.skipped(), 1);

        // A reopened store continues the numbering past the corrupt head.
        let mut reopened = CheckpointStore::open(&base, 4).unwrap();
        assert_eq!(reopened.save(b"payload-3").unwrap(), 4);
        std::fs::remove_dir_all(base.parent().unwrap()).unwrap();
    }

    #[test]
    fn all_generations_corrupt_is_an_error_not_a_fresh_start() {
        let base = temp_base("allbad");
        let mut cs = CheckpointStore::open(&base, 2).unwrap();
        cs.save(b"only").unwrap();
        let f = base.parent().unwrap().join("sess.ckpt.00001");
        std::fs::write(&f, b"not a sealed frame").unwrap();
        assert!(cs.load_latest().is_err());
        std::fs::remove_dir_all(base.parent().unwrap()).unwrap();
    }

    #[test]
    fn open_cleans_stray_tmp_files_and_decode_gates_recovery() {
        let base = temp_base("tmpclean");
        // A crash-at-sync leaves a full stray tmp behind.
        let fs = FsStore::open(base.parent().unwrap()).unwrap();
        let plan = IoFaultPlan::parse("crashsync@1").unwrap();
        let mut cs = CheckpointStore::with_store(
            Box::new(FaultStore::new(Box::new(fs), plan)),
            "sess.ckpt",
            3,
        )
        .unwrap();
        cs.save(b"good-1").unwrap();
        assert!(cs.save(b"lost-2").is_err(), "crash-at-sync write fails");
        assert!(base.parent().unwrap().join("sess.ckpt.00002.tmp").exists());

        // Reopen (plain backend): stray tmp cleaned, last good recovered.
        let mut reopened = CheckpointStore::open(&base, 3).unwrap();
        assert!(!base.parent().unwrap().join("sess.ckpt.00002.tmp").exists());
        let (generation, payload) = reopened.load_latest().unwrap().unwrap();
        assert_eq!((generation, payload.as_slice()), (1, b"good-1".as_slice()));

        // A frame-valid generation whose *payload* fails decode is
        // skipped too: recovery requires magic+checksum+decode.
        reopened.save(b"bad-payload").unwrap();
        let (generation, _) = reopened
            .load_latest_with(|p| {
                anyhow::ensure!(p != b"bad-payload", "decode rejects it");
                Ok(p.to_vec())
            })
            .unwrap()
            .unwrap();
        assert_eq!(generation, 1);
        assert_eq!(reopened.skipped(), 1);
        std::fs::remove_dir_all(base.parent().unwrap()).unwrap();
    }
}
