//! Fault model for the distributed sift path.
//!
//! Theorem 1 licenses more than stale models: a sift node that goes
//! silent is just a lane whose work arrives late — or never, in which
//! case the coordinator can recompute it locally from the same seeds and
//! get the *same bits* (shards and sifter coins are regenerated
//! deterministically; example data never crosses the wire). This module
//! supplies the vocabulary that makes that recovery testable:
//!
//! * [`NetError`] — the typed failure taxonomy every deadline-aware
//!   receive reports: a deadline expired ([`NetError::Timeout`]), the
//!   peer went away ([`NetError::Disconnected`]), or the peer sent bytes
//!   that do not decode ([`NetError::Garbage`]). Carried inside
//!   `anyhow::Error` chains; classify with [`NetError::classify`].
//! * [`FaultConfig`] — the coordinator's patience: per-receive deadline,
//!   retry budget, backoff seed. `node_timeout == None` (the default)
//!   keeps the legacy blocking behavior with zero overhead.
//! * [`RetryPolicy`] — deterministic exponential backoff with seeded
//!   jitter (no wall-clock entropy: same seed, same delays) used by the
//!   transport connect loops.
//! * [`FaultPlan`] / [`FaultInjectTransport`] — a scripted, seeded fault
//!   harness: drop/delay/disconnect/garbage events at chosen
//!   (round, node) points, injected by wrapping any real
//!   [`Transport`]. The plan syntax doubles as the `--chaos` CLI flag.
//!   `tests/fault_equivalence.rs` drives every recovery path through it
//!   and requires the final model to be bit-identical to the fault-free
//!   run.

use super::proto::peek_round;
use super::transport::Transport;
use crate::rng::Rng;
use anyhow::Result;
use std::time::Duration;

/// Typed network failure, carried inside `anyhow::Error`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NetError {
    /// The receive deadline expired with no complete frame.
    Timeout,
    /// The peer hung up (EOF, closed socket, dropped channel).
    Disconnected,
    /// A complete frame arrived but its bytes do not decode.
    Garbage(String),
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::Timeout => write!(f, "receive deadline expired"),
            NetError::Disconnected => write!(f, "peer disconnected"),
            NetError::Garbage(why) => write!(f, "undecodable frame: {why}"),
        }
    }
}

impl std::error::Error for NetError {}

impl NetError {
    /// The [`NetError`] inside `err`'s chain, if any — the coordinator's
    /// dead-or-slow triage reads this instead of string matching.
    pub fn classify(err: &anyhow::Error) -> Option<&NetError> {
        err.downcast_ref::<NetError>()
    }
}

/// The coordinator's fault-tolerance knobs (CLI: `--node-timeout`,
/// `--retries`). The default disables deadlines entirely: receives block
/// forever and any node error aborts the run, exactly the pre-fault
/// behavior.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// Per-receive deadline on node replies. `None` = block forever
    /// (legacy behavior; failover machinery fully disabled).
    pub node_timeout: Option<Duration>,
    /// Extra deadline-lengths to wait (with a heartbeat ping each) before
    /// declaring a silent node dead.
    pub retries: u32,
    /// Seed for deterministic backoff jitter.
    pub seed: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig { node_timeout: None, retries: 2, seed: 0xFA17 }
    }
}

impl FaultConfig {
    /// Enable deadlines/failover with the given per-receive timeout.
    pub fn with_timeout(timeout: Duration) -> Self {
        FaultConfig { node_timeout: Some(timeout), ..Default::default() }
    }

    /// Whether the failover machinery is active at all.
    pub fn enabled(&self) -> bool {
        self.node_timeout.is_some()
    }
}

/// Deterministic exponential backoff with seeded jitter: attempt `i`
/// sleeps `min(base << i, cap)` scaled by a uniform factor in [0.5, 1.0).
/// No wall-clock entropy — the same seed always produces the same delay
/// sequence, so connect races in tests replay exactly.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    base: Duration,
    cap: Duration,
    rng: Rng,
}

impl RetryPolicy {
    pub fn new(base: Duration, cap: Duration, seed: u64) -> Self {
        RetryPolicy { base, cap, rng: Rng::new(seed) }
    }

    /// Connect-loop defaults: 10 ms doubling to a 400 ms ceiling.
    pub fn for_connect(seed: u64) -> Self {
        RetryPolicy::new(Duration::from_millis(10), Duration::from_millis(400), seed)
    }

    /// Delay before retry number `attempt` (0-based).
    pub fn delay(&mut self, attempt: u32) -> Duration {
        let exp = self.base.saturating_mul(1u32.checked_shl(attempt.min(16)).unwrap_or(u32::MAX));
        let capped = exp.min(self.cap);
        capped.mul_f64(0.5 + 0.5 * self.rng.next_f64())
    }
}

/// What a scripted fault does to one (round, node) interaction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Swallow the node's reply once: the coordinator sees a timeout, the
    /// node believes it answered.
    DropReply,
    /// Hold the node's reply hostage through `times` receive attempts,
    /// then deliver it intact — a slow node, not a dead one.
    DelayReply { times: u32 },
    /// Sever the link for `rounds` round-broadcasts starting at the
    /// event's round: sends are swallowed, receives time out.
    Disconnect { rounds: u64 },
    /// Replace the node's reply with undecodable bytes.
    GarbageReply,
}

/// One scripted fault at a (round, node) coordinate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub round: u64,
    pub node: usize,
    pub kind: FaultKind,
}

/// A deterministic chaos schedule. Parsed from the `--chaos` CLI spec: a
/// comma-separated list of `drop@R:N`, `delay@R:NxT`, `disc@R:N+W`, and
/// `garbage@R:N` events (round `R`, node `N`, `T` held receives, `W`
/// disconnected rounds).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
    /// Seeds the garbage-byte generator (scripted plans stay fully
    /// deterministic).
    pub seed: u64,
}

impl FaultPlan {
    pub fn new(events: Vec<FaultEvent>, seed: u64) -> Self {
        FaultPlan { events, seed }
    }

    /// Parse a `--chaos` spec, e.g. `drop@3:0,delay@4:1x2,disc@5:0+3`.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut events = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (kind, coord) = part
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("bad chaos event {part:?}: missing '@'"))?;
            let (round_s, rest) = coord
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("bad chaos event {part:?}: missing ':'"))?;
            let round: u64 = round_s
                .parse()
                .map_err(|_| anyhow::anyhow!("bad chaos round {round_s:?} in {part:?}"))?;
            let parse_node = |s: &str| {
                s.parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("bad chaos node {s:?} in {part:?}"))
            };
            let event = match kind {
                "drop" => FaultEvent { round, node: parse_node(rest)?, kind: FaultKind::DropReply },
                "garbage" => {
                    FaultEvent { round, node: parse_node(rest)?, kind: FaultKind::GarbageReply }
                }
                "delay" => {
                    let (node_s, times_s) = rest.split_once('x').ok_or_else(|| {
                        anyhow::anyhow!("bad chaos event {part:?}: delay needs NxT")
                    })?;
                    let times: u32 = times_s
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad delay count {times_s:?} in {part:?}"))?;
                    FaultEvent {
                        round,
                        node: parse_node(node_s)?,
                        kind: FaultKind::DelayReply { times },
                    }
                }
                "disc" => {
                    let (node_s, rounds_s) = rest.split_once('+').ok_or_else(|| {
                        anyhow::anyhow!("bad chaos event {part:?}: disc needs N+W")
                    })?;
                    let rounds: u64 = rounds_s.parse().map_err(|_| {
                        anyhow::anyhow!("bad disconnect width {rounds_s:?} in {part:?}")
                    })?;
                    anyhow::ensure!(rounds >= 1, "disconnect width must be >= 1 in {part:?}");
                    FaultEvent { round, node: parse_node(node_s)?, kind: FaultKind::Disconnect { rounds } }
                }
                other => anyhow::bail!("unknown chaos kind {other:?} (drop|delay|disc|garbage)"),
            };
            events.push(event);
        }
        anyhow::ensure!(!events.is_empty(), "empty chaos spec");
        Ok(FaultPlan { events, seed: 0xC4A0_5000 })
    }
}

/// Per-node injection state.
#[derive(Debug, Default)]
struct NodeFaults {
    /// Reply bytes held back by an active delay event.
    held: Option<Vec<u8>>,
    /// Receive attempts left before a held reply is released.
    delays_left: u32,
    /// Link severed while `current round < until`.
    disconnected_until: u64,
}

/// A [`Transport`] wrapper that injects the scripted faults of a
/// [`FaultPlan`] at exact (round, node) points. Rounds are tracked by
/// peeking outgoing `Round` frames, so the wrapper needs no cooperation
/// from the coordinator. Every behavior is deterministic: same plan, same
/// run, same injected failures.
pub struct FaultInjectTransport {
    inner: Box<dyn Transport>,
    plan: FaultPlan,
    /// Events not yet triggered (an event fires on the first matching
    /// receive/send at or after its round).
    pending: Vec<bool>,
    round: u64,
    nodes: Vec<NodeFaults>,
    rng: Rng,
}

impl FaultInjectTransport {
    pub fn new(inner: Box<dyn Transport>, plan: FaultPlan) -> Self {
        let n = inner.nodes();
        let pending = vec![true; plan.events.len()];
        let rng = Rng::new(plan.seed);
        FaultInjectTransport {
            inner,
            plan,
            pending,
            round: 0,
            nodes: (0..n).map(|_| NodeFaults::default()).collect(),
            rng,
        }
    }

    /// Next pending event for `node` whose round has come.
    fn due_event(&self, node: usize) -> Option<usize> {
        self.plan
            .events
            .iter()
            .enumerate()
            .find(|(i, e)| self.pending[*i] && e.node == node && e.round <= self.round)
            .map(|(i, _)| i)
    }

    fn disconnected(&self, node: usize) -> bool {
        self.round < self.nodes[node].disconnected_until
    }

    /// Arm any disconnect events that start at the current round (checked
    /// on every send so the window opens before the Round frame passes).
    fn arm_disconnects(&mut self, node: usize) {
        while let Some(i) = self.due_event(node) {
            if let FaultKind::Disconnect { rounds } = self.plan.events[i].kind {
                self.pending[i] = false;
                self.nodes[node].disconnected_until = self.plan.events[i].round + rounds;
            } else {
                break;
            }
        }
    }

    fn inject_recv(&mut self, node: usize, timeout: Duration) -> Result<Vec<u8>> {
        self.arm_disconnects(node);
        if self.disconnected(node) {
            // Nothing can arrive through a severed link; report it as
            // silence immediately (real sockets would burn the deadline).
            return Err(anyhow::Error::new(NetError::Timeout));
        }
        // A held (delayed) reply is released once its count runs out.
        if self.nodes[node].held.is_some() {
            if self.nodes[node].delays_left > 0 {
                self.nodes[node].delays_left -= 1;
                return Err(anyhow::Error::new(NetError::Timeout));
            }
            return Ok(self.nodes[node].held.take().expect("held reply vanished"));
        }
        match self.due_event(node).map(|i| (i, self.plan.events[i].kind)) {
            Some((i, FaultKind::DropReply)) => {
                // Consume the real reply so the node believes it answered,
                // then report silence.
                let _ = self.inner.recv_from_deadline(node, timeout)?;
                self.pending[i] = false;
                Err(anyhow::Error::new(NetError::Timeout))
            }
            Some((i, FaultKind::GarbageReply)) => {
                let _ = self.inner.recv_from_deadline(node, timeout)?;
                self.pending[i] = false;
                let mut junk = vec![0xFFu8; 8];
                for b in junk.iter_mut() {
                    *b = (self.rng.next_u64() & 0xFF) as u8;
                }
                junk[0] = 0xFF; // never a valid message tag
                Ok(junk)
            }
            Some((i, FaultKind::DelayReply { times })) => {
                let bytes = self.inner.recv_from_deadline(node, timeout)?;
                self.pending[i] = false;
                self.nodes[node].held = Some(bytes);
                self.nodes[node].delays_left = times.saturating_sub(1);
                Err(anyhow::Error::new(NetError::Timeout))
            }
            _ => self.inner.recv_from_deadline(node, timeout),
        }
    }
}

/// Fetch deadline for faults that must consume the real reply when the
/// caller used a blocking receive.
const BLOCKING_FETCH: Duration = Duration::from_secs(10);

impl Transport for FaultInjectTransport {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn nodes(&self) -> usize {
        self.inner.nodes()
    }

    fn send_to(&mut self, node: usize, msg: &[u8]) -> Result<()> {
        if let Some(round) = peek_round(msg) {
            self.round = round;
        }
        self.arm_disconnects(node);
        if self.disconnected(node) {
            return Ok(()); // swallowed: the wire ate it
        }
        self.inner.send_to(node, msg)
    }

    fn recv_from(&mut self, node: usize) -> Result<Vec<u8>> {
        self.inject_recv(node, BLOCKING_FETCH)
    }

    fn recv_from_deadline(&mut self, node: usize, timeout: Duration) -> Result<Vec<u8>> {
        self.inject_recv(node, timeout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_finds_the_typed_error_through_context() {
        let err = anyhow::Error::new(NetError::Timeout).context("receiving from node 3");
        assert_eq!(NetError::classify(&err), Some(&NetError::Timeout));
        let plain = anyhow::anyhow!("some other failure");
        assert_eq!(NetError::classify(&plain), None);
        let garbage = anyhow::Error::new(NetError::Garbage("bad tag".into()));
        assert!(matches!(NetError::classify(&garbage), Some(NetError::Garbage(_))));
    }

    #[test]
    fn default_config_disables_failover() {
        let cfg = FaultConfig::default();
        assert!(!cfg.enabled());
        assert!(FaultConfig::with_timeout(Duration::from_millis(50)).enabled());
    }

    #[test]
    fn retry_policy_is_deterministic_bounded_and_growing() {
        let mut a = RetryPolicy::for_connect(7);
        let mut b = RetryPolicy::for_connect(7);
        let da: Vec<_> = (0..8).map(|i| a.delay(i)).collect();
        let db: Vec<_> = (0..8).map(|i| b.delay(i)).collect();
        assert_eq!(da, db, "same seed must give the same delays");
        for (i, d) in da.iter().enumerate() {
            assert!(*d <= Duration::from_millis(400), "attempt {i} over cap: {d:?}");
            assert!(*d >= Duration::from_millis(5), "attempt {i} under base/2: {d:?}");
        }
        // Exponential phase: later attempts are (stochastically) longer;
        // attempt 6 is capped at >= 200ms while attempt 0 is <= 10ms.
        assert!(da[6] > da[0]);
        // A huge attempt index must not overflow.
        let _ = a.delay(u32::MAX);
    }

    #[test]
    fn plan_parser_roundtrips_every_kind_and_rejects_junk() {
        let plan = FaultPlan::parse("drop@3:0, delay@4:1x2, disc@5:0+3, garbage@6:1").unwrap();
        assert_eq!(
            plan.events,
            vec![
                FaultEvent { round: 3, node: 0, kind: FaultKind::DropReply },
                FaultEvent { round: 4, node: 1, kind: FaultKind::DelayReply { times: 2 } },
                FaultEvent { round: 5, node: 0, kind: FaultKind::Disconnect { rounds: 3 } },
                FaultEvent { round: 6, node: 1, kind: FaultKind::GarbageReply },
            ]
        );
        assert!(FaultPlan::parse("").is_err());
        assert!(FaultPlan::parse("drop@x:0").is_err());
        assert!(FaultPlan::parse("drop@1").is_err());
        assert!(FaultPlan::parse("delay@1:0").is_err(), "delay needs a count");
        assert!(FaultPlan::parse("disc@1:0").is_err(), "disc needs a width");
        assert!(FaultPlan::parse("disc@1:0+0").is_err(), "zero-width disconnect");
        assert!(FaultPlan::parse("explode@1:0").is_err());
    }
}
