//! The distributed coordinator — Algorithm 1's round loop over a wire.
//!
//! [`run_distributed`] drives `p` remote node processes (each hosting a
//! contiguous slice of the k lanes) through the same round schedule as
//! the in-process loops, and is **bit-identical** to them by
//! construction:
//!
//! * `stale = 0` mirrors [`sync::run_rounds`]'s direct path: every
//!   round's selections are applied before the next round's sync is
//!   encoded, so nodes sift with last round's fully-updated model;
//! * `stale = 1` mirrors the pipelined loop
//!   ([`crate::coordinator::pipeline`]): the sync is encoded from the
//!   live model **before** the pending replay flushes — the wire
//!   snapshot plays the role of the pipelined `learner.clone()` — and
//!   the flush overlaps the remote sift in real time. Nodes therefore
//!   sift round t with the model of round t−2, exactly the
//!   `ReplayConfig::stale(·, 1)` trajectory.
//!
//! Budgets ≥ 2 would stack wire lag on top of replay lag and leave the
//! equivalence contract unverifiable, so they are rejected loudly.
//!
//! Wall-clock caveat: `wall.sift` covers broadcast → last reply, which
//! includes wire time; the simulated [`RoundClock`] still charges only
//! the nodes' self-reported sift seconds plus the [`CommModel`], so the
//! simulated numbers stay comparable with in-process runs.
//!
//! [`sync::run_rounds`]: crate::coordinator::sync

use super::delta::ModelCodec;
use super::fault::{FaultConfig, NetError};
use super::proto::{InitMsg, Msg, RoundMsg, TaskKind, PROTO_VERSION};
use super::transport::{Transport, FRAME_OVERHEAD};
use super::NetStats;
use crate::active::SifterSpec;
use crate::coordinator::backend::NodeSift;
use crate::coordinator::sync::{
    make_lane, record, warmstart_phase, CostCounters, NodeLane, SyncConfig, SyncReport, WallTimes,
};
use crate::data::{StreamConfig, TestSet, DIM};
use crate::exec::{PoolStats, ReplayExecutor, ReplayOutcome};
use crate::learner::{Learner, SiftScorer};
use crate::metrics::ErrorCurve;
use crate::sim::{NodeProfile, RoundClock, Stopwatch};
use anyhow::Result;
use std::time::{Duration, Instant};

/// FNV-1a digest over the little-endian bytes of `parts` — the run-config
/// fingerprint carried in [`InitMsg`]. Both processes fold the same
/// out-of-band configuration (learner hyper-parameters as f64 bits,
/// batch/warmstart/budget, seeds) so a node launched with different flags
/// fails the handshake instead of silently diverging.
pub fn config_fingerprint(parts: &[u64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for part in parts {
        for b in part.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

/// Contiguous lane slice owned by node process `j` of `p`: lanes are
/// spread as evenly as integer arithmetic allows, every process gets at
/// least one when `k >= p`.
pub(crate) fn lane_range(k: usize, p: usize, j: usize) -> (usize, usize) {
    (j * k / p, (j + 1) * k / p)
}

/// A transport wrapper charging every frame (payload + length prefix) to
/// the [`NetStats`] byte counters. Doubles as the orphan guard: until
/// the run reaches its normal shutdown, dropping the `Wire` (any `?` /
/// `bail!` path out of [`run_distributed`]) broadcasts a best-effort
/// `Shutdown` so node processes blocked on `recv` exit instead of
/// leaking forever.
struct Wire<'a> {
    t: &'a mut dyn Transport,
    stats: NetStats,
    /// Set once the shutdown round has been sent deliberately.
    finished: bool,
}

impl Wire<'_> {
    fn send(&mut self, node: usize, msg: &Msg) -> Result<()> {
        let _sp = crate::obs_span!("net.send", node = node as i64);
        let bytes = msg.encode()?;
        self.stats.bytes_sent += bytes.len() as u64 + FRAME_OVERHEAD;
        self.t.send_to(node, &bytes)
    }

    fn broadcast(&mut self, msg: &Msg) -> Result<()> {
        let _sp = crate::obs_span!("net.send");
        let bytes = msg.encode()?;
        self.stats.bytes_sent += (bytes.len() as u64 + FRAME_OVERHEAD) * self.t.nodes() as u64;
        self.t.broadcast(&bytes)
    }

    /// Best-effort point-to-point send: delivery failures are the
    /// receiver's problem (the next receive classifies the node as
    /// dead); bytes are only charged when the carrier took the frame.
    fn send_best_effort(&mut self, node: usize, msg: &Msg) {
        let _sp = crate::obs_span!("net.send", node = node as i64);
        if let Ok(bytes) = msg.encode() {
            if self.t.send_to(node, &bytes).is_ok() {
                self.stats.bytes_sent += bytes.len() as u64 + FRAME_OVERHEAD;
            }
        }
    }

    fn recv(&mut self, node: usize) -> Result<Msg> {
        let _sp = crate::obs_span!("net.recv", node = node as i64);
        let bytes = self.t.recv_from(node)?;
        self.stats.bytes_received += bytes.len() as u64 + FRAME_OVERHEAD;
        Msg::decode(&bytes)
            .map_err(|e| anyhow::Error::new(NetError::Garbage(e.to_string())))
    }

    /// Deadline-aware receive; a frame that arrives but does not decode
    /// classifies as [`NetError::Garbage`].
    fn recv_deadline(&mut self, node: usize, timeout: Duration) -> Result<Msg> {
        let _sp = crate::obs_span!("net.recv", node = node as i64);
        let bytes = self.t.recv_from_deadline(node, timeout)?;
        self.stats.bytes_received += bytes.len() as u64 + FRAME_OVERHEAD;
        Msg::decode(&bytes)
            .map_err(|e| anyhow::Error::new(NetError::Garbage(e.to_string())))
    }
}

impl Drop for Wire<'_> {
    fn drop(&mut self) {
        if self.finished {
            return;
        }
        if let Ok(bytes) = Msg::Shutdown.encode() {
            // Per-node, ignoring errors: the default broadcast stops at
            // the first failure, which would skip the remaining nodes.
            for node in 0..self.t.nodes() {
                let _ = self.t.send_to(node, &bytes);
            }
        }
    }
}

/// Run Algorithm 1 with the sift phase distributed over `transport`'s
/// node processes. The learner and its update replay stay on this
/// (coordinator) side; nodes hold scoring replicas refreshed through
/// `codec` each round. `fingerprint` must equal what the node processes
/// were launched with ([`config_fingerprint`]).
///
/// `cfg.backend` is ignored — each node picks its own execution backend —
/// and `cfg.replay.max_stale_rounds` must be 0 or 1 (see module docs).
///
/// `faults` selects the failure policy. With `node_timeout == None`
/// (the default) receives block forever and any node error aborts the
/// run — the legacy behavior, byte for byte. With a timeout set, a node
/// that misses its deadline is retried (`faults.retries` heartbeat
/// pings), then declared dead and **failed over**: its lane range is
/// regenerated locally (same seeds, same coins — data never crossed the
/// wire) and sifted on the coordinator, so the trajectory stays
/// bit-identical to the fault-free run. A dead node that answers a
/// later heartbeat is re-adopted with a full-snapshot resync
/// (`scorer` drives the local failover sifts).
#[allow(clippy::too_many_arguments)]
pub fn run_distributed<L: Learner + Clone>(
    learner: &mut L,
    codec: &mut dyn ModelCodec<L>,
    sifter: &SifterSpec,
    stream_cfg: &StreamConfig,
    test: &TestSet,
    cfg: &SyncConfig,
    transport: &mut dyn Transport,
    task: TaskKind,
    fingerprint: u64,
    scorer: &dyn SiftScorer<L>,
    faults: &FaultConfig,
) -> Result<SyncReport> {
    anyhow::ensure!(cfg.nodes >= 1, "need at least one lane");
    anyhow::ensure!(
        cfg.global_batch >= cfg.nodes,
        "global batch {} smaller than the lane count {} — every lane needs at \
         least one example per round",
        cfg.global_batch,
        cfg.nodes
    );
    let stale = cfg.replay.max_stale_rounds;
    anyhow::ensure!(
        stale <= 1,
        "distributed runs support max_stale_rounds 0 (strict) or 1 (overlapped); \
         {stale} would stack wire lag on top of replay lag"
    );
    let k = cfg.nodes;
    let p = transport.nodes();
    anyhow::ensure!(
        p >= 1 && k >= p,
        "{p} node processes but only {k} lanes — launch at most one process per lane"
    );
    let shard = cfg.global_batch / k;
    let overlapped = stale == 1;
    let ft_on = faults.enabled();
    let timeout = faults.node_timeout.unwrap_or_default();
    let needs_scores = sifter.needs_scores();

    let profile = cfg.profile.clone().unwrap_or_else(|| NodeProfile::uniform(k));
    assert_eq!(profile.k(), k);
    let mut clock = RoundClock::new(profile, cfg.comm);
    let mut costs = CostCounters::default();
    let mut wall = WallTimes::default();
    let mut replay = ReplayExecutor::new(cfg.replay, DIM);
    let mut total_sw = Stopwatch::start();
    let mut wire = Wire { t: transport, stats: NetStats::default(), finished: false };

    // Failover state (only touched when `ft_on`): which processes are
    // believed alive, the locally regenerated lanes of dead ones, and
    // whether the next sync must be a full snapshot (re-adoption).
    let mut alive = vec![true; p];
    let mut dead_lanes: Vec<Option<Vec<NodeLane>>> = (0..p).map(|_| None).collect();
    let mut force_full = false;
    let mut ping_seq: u64 = 0;

    // --- Handshake: hand every process its lane slice. ---
    for j in 0..p {
        let (lo, hi) = lane_range(k, p, j);
        wire.send(
            j,
            &Msg::Init(InitMsg {
                version: PROTO_VERSION,
                task,
                fingerprint,
                node_index: j as u32,
                lane_lo: lo as u32,
                lane_hi: hi as u32,
                k: k as u32,
                shard: shard as u32,
                skip: if lo == 0 { cfg.warmstart as u64 } else { 0 },
                stream_seed: stream_cfg.seed,
                sifter: sifter.clone(),
            }),
        )?;
    }
    for j in 0..p {
        match wire.recv(j)? {
            Msg::Ready(r) => {
                let (lo, hi) = lane_range(k, p, j);
                anyhow::ensure!(
                    r.node_index == j as u32 && r.lanes as usize == hi - lo,
                    "node {j} acked as index {} with {} lanes (expected {})",
                    r.node_index,
                    r.lanes,
                    hi - lo
                );
            }
            other => anyhow::bail!("expected ready from node {j}, got {other:?}"),
        }
    }

    let mut curve = ErrorCurve::new(cfg.label.clone());
    let mut n_seen: u64 = 0;
    let mut n_queried: u64 = 0;

    // --- Warmstart: passive training on the head of node 0's stream,
    // consumed locally; lane 0's remote stream skips the same head. ---
    let mut lane0 = make_lane(stream_cfg, sifter, 0, 1);
    warmstart_phase(
        learner,
        &mut lane0,
        cfg.warmstart,
        &mut clock,
        &mut costs,
        &mut wall,
        &mut n_seen,
    );
    record(&mut curve, &clock, learner, test, n_seen, n_queried);

    // --- Rounds. Epoch = round index; the guard on the node side holds
    // the codecs to strictly consecutive delta application. ---
    let mut round: u64 = 0;
    while (n_seen as usize) < cfg.budget {
        round += 1;
        let n_phase = n_seen;
        let _sp_round = crate::obs_span!("round", round = round as i64);

        // Probe dead nodes before encoding: a node that answers the
        // heartbeat is re-adopted *this* round, which forces the sync
        // below to be a full snapshot (accepted by its epoch guard at
        // any forward epoch — and broadcast to everyone, so the delta
        // codecs' slot tables stay in lockstep).
        if ft_on {
            for j in 0..p {
                if alive[j] {
                    continue;
                }
                ping_seq += 1;
                wire.send_best_effort(j, &Msg::Ping(ping_seq));
                let deadline = Instant::now() + timeout;
                loop {
                    let remaining = deadline.saturating_duration_since(Instant::now());
                    if remaining.is_zero() {
                        break;
                    }
                    match wire.recv_deadline(j, remaining) {
                        Ok(Msg::Pong(_)) => {
                            alive[j] = true;
                            dead_lanes[j] = None;
                            force_full = true;
                            wire.stats.reconnects += 1;
                            crate::obs::counter("net.reconnects").add(1);
                            break;
                        }
                        // Stale replies queued from before the failure.
                        Ok(_) => continue,
                        Err(_) => break, // still dead
                    }
                }
            }
        }

        // Encode the sync before the overlapped flush (stale=1): the wire
        // snapshot is the pipelined loop's `learner.clone()` — nodes sift
        // round t with the model of round t-2. Under stale=0 the previous
        // round was already applied, so this is the fully-updated model.
        let sp_sync = crate::obs_span!("sync", round = round as i64);
        let sync = if force_full {
            force_full = false;
            codec.encode_full(round, learner)?
        } else {
            codec.encode(round, learner)?
        };
        let live = if ft_on { alive.iter().filter(|a| **a).count() as u64 } else { p as u64 };
        wire.stats.sync_messages += live;
        wire.stats.sync_bytes += sync.payload.len() as u64 * live;
        wire.stats.full_equiv_bytes += codec.last_full_bytes() * live;
        if sync.full {
            wire.stats.full_syncs += live;
        } else {
            wire.stats.delta_syncs += live;
        }
        // Failover sifts must score against exactly the model the sync
        // describes. Under stale=1 the overlapped flush below mutates
        // the learner after the encode, so snapshot now; under stale=0
        // the learner is untouched until merge and `learner` itself
        // serves as the frozen model.
        let frozen_snapshot: Option<L> = (ft_on && overlapped).then(|| learner.clone());

        let mut sw = Stopwatch::start();
        let round_msg = Msg::Round(RoundMsg { round, n_phase, sync });
        if ft_on {
            for j in 0..p {
                if alive[j] {
                    wire.send_best_effort(j, &round_msg);
                }
            }
        } else {
            wire.broadcast(&round_msg)?;
        }
        drop(sp_sync);

        // Replay of round t-1 overlaps the remote sift in real time.
        let mut update_secs = 0.0;
        let mut applied = ReplayOutcome::default();
        if overlapped {
            let _sp = crate::obs_span!("update", round = round as i64 - 1);
            let mut usw = Stopwatch::start();
            applied.absorb(replay.flush(learner));
            update_secs += usw.lap();
        }

        // Collect replies in process order; lanes arrive in lane order
        // within each, so the pool is node-major — the ordered-broadcast
        // guarantee, same as the in-process sessions. Under fault
        // tolerance a node that stays silent past its deadline (plus
        // retries) or hands back garbage is declared dead and its lane
        // range is sifted locally, in place, at the same node-major
        // position — same seeds, same coins, same bits.
        let mut results: Vec<NodeSift> = Vec::with_capacity(k);
        for j in 0..p {
            let (lo, hi) = lane_range(k, p, j);
            if !ft_on {
                match wire.recv(j)? {
                    Msg::Sift(s) => {
                        anyhow::ensure!(
                            s.round == round && s.lanes.len() == hi - lo,
                            "node {j} answered round {} with {} lanes (expected round \
                             {round} with {})",
                            s.round,
                            s.lanes.len(),
                            hi - lo
                        );
                        results.extend(s.lanes);
                    }
                    other => anyhow::bail!("expected sift results from node {j}, got {other:?}"),
                }
                continue;
            }

            let mut local = !alive[j];
            if !local {
                let mut attempts = 0u32;
                loop {
                    match wire.recv_deadline(j, timeout) {
                        Ok(Msg::Sift(s)) if s.round == round => {
                            anyhow::ensure!(
                                s.lanes.len() == hi - lo,
                                "node {j} answered round {round} with {} lanes (expected {})",
                                s.lanes.len(),
                                hi - lo
                            );
                            results.extend(s.lanes);
                            break;
                        }
                        // Stale sift replies (a round we already failed
                        // over) and heartbeat echoes are drained, not
                        // counted against the deadline budget.
                        Ok(Msg::Sift(_)) | Ok(Msg::Pong(_)) => continue,
                        Ok(_confused) => {
                            alive[j] = false;
                            local = true;
                            break;
                        }
                        Err(e) => match NetError::classify(&e) {
                            Some(NetError::Timeout) => {
                                wire.stats.timeouts += 1;
                                crate::obs::counter("net.timeouts").add(1);
                                if attempts >= faults.retries {
                                    alive[j] = false;
                                    local = true;
                                    break;
                                }
                                attempts += 1;
                                wire.stats.retries += 1;
                                crate::obs::counter("net.retries").add(1);
                                ping_seq += 1;
                                wire.send_best_effort(j, &Msg::Ping(ping_seq));
                            }
                            // Disconnected, garbage, or unclassified:
                            // no amount of waiting helps.
                            _ => {
                                alive[j] = false;
                                local = true;
                                break;
                            }
                        },
                    }
                }
            }
            if local {
                let _sp = crate::obs_span!("failover", round = round as i64, node = j as i64);
                wire.stats.failovers += 1;
                crate::obs::counter("net.failovers").add(1);
                let lanes = dead_lanes[j].get_or_insert_with(|| {
                    // Regenerate the dead node's lanes from scratch and
                    // replay every draw it already consumed: the
                    // warmstart head (stream only — warmstart never
                    // touched the sifter) and (round-1) shards' worth of
                    // examples and sifter coins per lane.
                    let mut lanes: Vec<NodeLane> =
                        (lo..hi).map(|n| make_lane(stream_cfg, sifter, n, shard)).collect();
                    if lo == 0 && cfg.warmstart > 0 {
                        let mut x = vec![0.0f32; DIM];
                        for _ in 0..cfg.warmstart {
                            lanes[0].stream.next_into(&mut x);
                        }
                    }
                    for lane in lanes.iter_mut() {
                        lane.fast_forward((round - 1) as usize * shard);
                    }
                    lanes
                });
                let frozen: &L = frozen_snapshot.as_ref().map_or(&*learner, |s| s);
                for lane in lanes.iter_mut() {
                    lane.stream.next_batch_into(&mut lane.xs, &mut lane.ys);
                    results.push(lane.sift_round(frozen, scorer, shard, n_phase, needs_scores, 0));
                }
            }
        }
        wall.sift += sw.lap();
        n_seen += (k * shard) as u64;

        // Passive updating, pooled node-major — identical to the
        // in-process loops' handling of `results`.
        let sp_merge = crate::obs_span!("merge", round = round as i64);
        let mut ssw = Stopwatch::start();
        let mut selected = 0usize;
        for node in &results {
            if overlapped {
                replay.submit_node(&node.sel_x, &node.sel_y, &node.sel_w);
            } else {
                let out = replay.apply_node_direct(learner, &node.sel_x, &node.sel_y, &node.sel_w);
                applied.absorb(out);
            }
            selected += node.sel_y.len();
            costs.sift_ops += node.sift_ops;
        }
        if overlapped {
            replay.end_round();
        }
        drop(sp_merge);
        update_secs += ssw.lap();
        costs.update_ops += applied.update_ops;
        wall.update += update_secs;
        n_queried += selected as u64;
        costs.broadcasts += selected as u64;

        let node_sift: Vec<f64> = results.iter().map(|r| r.seconds).collect();
        if overlapped {
            clock.charge_round_overlapped(&node_sift, update_secs, selected, DIM * 4);
        } else {
            clock.charge_round(&node_sift, update_secs, selected, DIM * 4);
        }

        let do_eval =
            cfg.eval_every_rounds > 0 && clock.rounds() % cfg.eval_every_rounds as u64 == 0;
        if do_eval {
            record(&mut curve, &clock, learner, test, n_seen, n_queried);
        }
    }

    // Drain the round still in flight (stale=1) so the final model has
    // absorbed every broadcast selection.
    if replay.pending_examples() > 0 {
        let _sp = crate::obs_span!("update");
        let mut sw = Stopwatch::start();
        let tail = replay.flush(learner);
        let tail_secs = sw.lap();
        costs.update_ops += tail.update_ops;
        wall.update += tail_secs;
        clock.charge_update(tail_secs);
    }
    record(&mut curve, &clock, learner, test, n_seen, n_queried);

    // --- Shutdown: collect each process's pool counters. ---
    let mut pool = PoolStats::default();
    if ft_on {
        // Best-effort to every process, dead ones included — a
        // disconnected-but-running node exits on it or on transport
        // teardown, never blocks forever. Byes are only awaited from
        // live nodes, draining any stale replies, and a node that dies
        // during shutdown forfeits its counters instead of the run.
        for j in 0..p {
            wire.send_best_effort(j, &Msg::Shutdown);
        }
        for j in 0..p {
            if !alive[j] {
                continue;
            }
            let deadline = Instant::now() + timeout;
            loop {
                let remaining = deadline.saturating_duration_since(Instant::now());
                if remaining.is_zero() {
                    break;
                }
                match wire.recv_deadline(j, remaining) {
                    Ok(Msg::Bye(b)) => {
                        pool.workers += b.pool.workers;
                        pool.threads_spawned += b.pool.threads_spawned;
                        pool.rounds = pool.rounds.max(b.pool.rounds);
                        break;
                    }
                    Ok(_) => continue,
                    Err(_) => break,
                }
            }
        }
    } else {
        wire.broadcast(&Msg::Shutdown)?;
        for j in 0..p {
            match wire.recv(j)? {
                Msg::Bye(b) => {
                    pool.workers += b.pool.workers;
                    pool.threads_spawned += b.pool.threads_spawned;
                    pool.rounds = pool.rounds.max(b.pool.rounds);
                }
                other => anyhow::bail!("expected bye from node {j}, got {other:?}"),
            }
        }
    }
    wire.finished = true;
    wall.total = total_sw.lap();

    Ok(SyncReport {
        rounds: clock.rounds(),
        n_seen,
        n_queried,
        elapsed: clock.elapsed_seconds(),
        sift_time: clock.sift_time,
        update_time: clock.update_time,
        warmstart_time: clock.warmstart_time,
        comm_time: clock.comm_time,
        obs: crate::obs::ObsReport::fold_sync(&wall, &pool, &wire.stats),
        wall,
        backend: wire.t.name(),
        pipelined: overlapped,
        pool,
        replay: replay.stats(),
        net: wire.stats,
        costs,
        curve,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SerialBackend;
    use crate::coordinator::sync::run_sync;
    use crate::exec::ReplayConfig;
    use crate::learner::NativeScorer;
    use crate::net::delta::SvmDeltaCodec;
    use crate::net::node::serve_sift_node;
    use crate::net::transport::{InProcChannel, InProcTransport};
    use crate::svm::{lasvm::LaSvm, LaSvmConfig, RbfKernel};

    #[test]
    fn fingerprint_is_stable_and_sensitive() {
        let a = config_fingerprint(&[1, 2, 3]);
        assert_eq!(a, config_fingerprint(&[1, 2, 3]));
        assert_ne!(a, config_fingerprint(&[1, 2, 4]));
        assert_ne!(a, config_fingerprint(&[1, 2]));
        assert_ne!(config_fingerprint(&[]), 0);
    }

    #[test]
    fn lane_ranges_partition_contiguously() {
        for k in 1..=9 {
            for p in 1..=k {
                let mut next = 0;
                for j in 0..p {
                    let (lo, hi) = lane_range(k, p, j);
                    assert_eq!(lo, next, "gap at process {j} (k={k}, p={p})");
                    assert!(hi > lo, "empty slice at process {j} (k={k}, p={p})");
                    next = hi;
                }
                assert_eq!(next, k);
            }
        }
    }

    fn spawn_svm_node(
        mut chan: InProcChannel,
        fingerprint: u64,
    ) -> std::thread::JoinHandle<Result<crate::net::SiftNodeReport>> {
        std::thread::spawn(move || {
            let mut replica = LaSvm::new(RbfKernel::paper(), DIM, LaSvmConfig::default());
            let mut codec = SvmDeltaCodec::new(DIM);
            serve_sift_node(
                &mut chan,
                &mut replica,
                &mut codec,
                &NativeScorer,
                &SerialBackend,
                &StreamConfig::svm_task(),
                TaskKind::Svm,
                fingerprint,
            )
        })
    }

    #[test]
    fn distributed_inproc_matches_run_sync_strict() {
        let stream_cfg = StreamConfig::svm_task();
        let test = TestSet::generate(&stream_cfg, 100);
        let sifter = SifterSpec::margin(0.1, 7);
        let cfg = SyncConfig::new(2, 200, 100, 900);

        let mut reference = LaSvm::new(RbfKernel::paper(), DIM, LaSvmConfig::default());
        let want = run_sync(&mut reference, &sifter, &stream_cfg, &test, &cfg, &NativeScorer);

        let fp = config_fingerprint(&[0x51, 2, 200]);
        let (mut hub, chans) = InProcTransport::pair(1);
        let handles: Vec<_> = chans.into_iter().map(|c| spawn_svm_node(c, fp)).collect();
        let mut learner = LaSvm::new(RbfKernel::paper(), DIM, LaSvmConfig::default());
        let mut codec = SvmDeltaCodec::new(DIM);
        let got = run_distributed(
            &mut learner,
            &mut codec,
            &sifter,
            &stream_cfg,
            &test,
            &cfg,
            &mut hub,
            TaskKind::Svm,
            fp,
            &NativeScorer,
            &FaultConfig::default(),
        )
        .unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }

        assert_eq!(got.backend, "inproc");
        assert!(!got.pipelined);
        assert_eq!(got.rounds, want.rounds);
        assert_eq!(got.n_seen, want.n_seen);
        assert_eq!(got.n_queried, want.n_queried);
        assert_eq!(got.costs.sift_ops, want.costs.sift_ops);
        assert_eq!(got.costs.update_ops, want.costs.update_ops);
        assert_eq!(
            got.final_test_errors().to_bits(),
            want.final_test_errors().to_bits(),
            "distributed trajectory drifted from the in-process loop"
        );
        // Wire telemetry is live: every round synced every process, the
        // first sync was full, and later syncs were deltas that beat it.
        assert_eq!(got.net.sync_messages, got.rounds);
        assert_eq!(got.net.full_syncs + got.net.delta_syncs, got.net.sync_messages);
        assert!(got.net.delta_syncs > 0);
        assert!(got.net.delta_ratio() < 1.0, "ratio {}", got.net.delta_ratio());
        assert!(got.net.bytes_sent > 0 && got.net.bytes_received > 0);
    }

    #[test]
    fn distributed_rejects_deep_staleness_and_too_many_processes() {
        let stream_cfg = StreamConfig::svm_task();
        let test = TestSet::generate(&stream_cfg, 10);
        let sifter = SifterSpec::margin(0.1, 7);
        let mut learner = LaSvm::new(RbfKernel::paper(), DIM, LaSvmConfig::default());
        let mut codec = SvmDeltaCodec::new(DIM);

        let cfg = SyncConfig::new(2, 100, 50, 400).with_replay(ReplayConfig::stale(16, 2));
        let (mut hub, _chans) = InProcTransport::pair(1);
        let err = run_distributed(
            &mut learner,
            &mut codec,
            &sifter,
            &stream_cfg,
            &test,
            &cfg,
            &mut hub,
            TaskKind::Svm,
            0,
            &NativeScorer,
            &FaultConfig::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("max_stale_rounds"), "{err}");

        let cfg = SyncConfig::new(2, 100, 50, 400);
        let (mut hub, _chans) = InProcTransport::pair(3);
        let err = run_distributed(
            &mut learner,
            &mut codec,
            &sifter,
            &stream_cfg,
            &test,
            &cfg,
            &mut hub,
            TaskKind::Svm,
            0,
            &NativeScorer,
            &FaultConfig::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("lanes"), "{err}");
    }

    #[test]
    fn bail_paths_shut_down_connected_nodes() {
        // One healthy node plus one that misbehaves in the handshake:
        // the coordinator bails, and the Wire drop guard must still
        // deliver a Shutdown so the healthy node exits instead of
        // blocking on recv forever (the join below would hang).
        let stream_cfg = StreamConfig::svm_task();
        let test = TestSet::generate(&stream_cfg, 10);
        let sifter = SifterSpec::margin(0.1, 7);
        let cfg = SyncConfig::new(2, 100, 50, 400);
        let fp = config_fingerprint(&[0x77]);

        let (mut hub, mut chans) = InProcTransport::pair(2);
        let bad_chan = chans.pop().unwrap();
        let good = spawn_svm_node(chans.pop().unwrap(), fp);
        let bad = std::thread::spawn(move || {
            let mut chan = bad_chan;
            use crate::net::transport::Channel;
            let _init = chan.recv().unwrap();
            // Answer the handshake with nonsense instead of Ready.
            chan.send(&Msg::Shutdown.encode().unwrap()).unwrap();
        });

        let mut learner = LaSvm::new(RbfKernel::paper(), DIM, LaSvmConfig::default());
        let mut codec = SvmDeltaCodec::new(DIM);
        let err = run_distributed(
            &mut learner,
            &mut codec,
            &sifter,
            &stream_cfg,
            &test,
            &cfg,
            &mut hub,
            TaskKind::Svm,
            fp,
            &NativeScorer,
            &FaultConfig::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("expected ready"), "{err}");
        bad.join().unwrap();
        // The guard's best-effort Shutdown lets the healthy node finish
        // with a clean report.
        let report = good.join().unwrap().unwrap();
        assert_eq!(report.rounds, 0);
    }
}
