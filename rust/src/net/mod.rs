//! L4 distribution — sift nodes beyond the coordinator's process.
//!
//! The paper's core claim is that the *search* for informative examples
//! parallelizes trivially and tolerates a slightly outdated model
//! (Theorem 1) — which means sift nodes never need shared memory, only a
//! periodic model sync. This module turns the in-process coordinator of
//! [`crate::coordinator`] into a topology:
//!
//! * [`transport`] — a [`Transport`](transport::Transport) hub over
//!   length-prefix-framed byte messages, with three interchangeable
//!   carriers: [`InProcTransport`](transport::InProcTransport) (mpsc
//!   channels, the single-process path as just another impl),
//!   [`UdsTransport`](transport::UdsTransport) (Unix-domain sockets) and
//!   loopback TCP ([`TcpTransport`](transport::TcpTransport));
//! * [`proto`] — the coordinator ↔ node message set (init/round/sift/
//!   shutdown) and its hand-rolled little-endian encoding (the vendor set
//!   is fixed, so no serde);
//! * [`delta`] — epoch-versioned **model-delta** codecs. The LASVM
//!   support set accrues mostly monotonically and alphas move in place,
//!   so [`delta::SvmDeltaCodec`] ships per-epoch deltas (new SVs in full,
//!   known SVs as slot references plus their alphas, plus the bias) with
//!   a full-state fallback whenever the delta would not beat the full
//!   snapshot; [`delta::MlpDenseCodec`] ships the MLP's dense weight
//!   state the same way (sparse index/value diffs with the identical
//!   fallback — AdaGrad touches every parameter, so full-state usually
//!   wins there, and the telemetry says so honestly);
//! * [`node`] — the remote sift-node serve loop
//!   ([`node::serve_sift_node`]): rebuilds its lanes (node-seeded streams
//!   and sifter RNGs) locally from the init message — example data never
//!   crosses the wire, only model state and selections — and runs them on
//!   the PR 3 execution pool via any [`SiftBackend`];
//! * [`cluster`] — the distributed coordinator round loop
//!   ([`cluster::run_distributed`]), bit-identical to the in-process
//!   loops under `stale ∈ {0, 1}` (`tests/transport_equivalence.rs`).
//!
//! [`SiftBackend`]: crate::coordinator::backend::SiftBackend
//!
//! **The equivalence contract, extended.** Every layer so far (threads,
//! pools, replay, pipelining) reproduced the serial reference bit for
//! bit; distribution is held to the same bar. A remote node regenerates
//! exactly the lanes the in-process coordinator would have built
//! (identical streams, identical sifter coins), scores them against a
//! replica whose scoring view was installed from the sync message with
//! the source model's exact bits, and returns selections in lane order —
//! so the coordinator pools the identical broadcast and the trajectory
//! cannot move. The `stale=1` wire schedule mirrors the pipelined loop
//! (sync encodes the live model *before* the overlapped replay flush);
//! `stale=0` mirrors the strict loop (replay applies before the next
//! encode). Higher staleness budgets would compound wire lag on top of
//! replay lag, so the distributed runner rejects them loudly.

//!
//! * [`fault`] — the resilience layer: typed
//!   [`NetError`](fault::NetError)s behind deadline-aware receives,
//!   deterministic retry/backoff, coordinator-side **lane failover**
//!   (a dead node's lanes are regenerated and sifted locally,
//!   bit-identically — Theorem 1's staleness tolerance extended to lost
//!   nodes), and a scripted [`FaultInjectTransport`](fault::
//!   FaultInjectTransport) that makes every recovery path deterministic
//!   to test (`tests/fault_equivalence.rs`).

pub mod cluster;
pub mod delta;
pub mod fault;
pub mod node;
pub mod proto;
pub mod transport;
pub(crate) mod wire;

pub use cluster::{config_fingerprint, run_distributed};
pub use delta::{MlpDenseCodec, ModelCodec, SvmDeltaCodec, SyncMessage};
pub use fault::{FaultConfig, FaultEvent, FaultInjectTransport, FaultKind, FaultPlan, NetError};
pub use node::{serve_sift_node, SiftNodeReport};
pub use proto::TaskKind;
pub use transport::{Channel, InProcTransport, TcpTransport, Transport, UdsTransport};

/// Wire telemetry of a distributed run, reported beside
/// [`WallTimes`](crate::coordinator::sync::WallTimes) on the
/// [`SyncReport`](crate::coordinator::sync::SyncReport). In-process runs
/// leave it zeroed (`sync_messages == 0` marks "no wire").
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct NetStats {
    /// Total frame bytes coordinator → nodes (sync payloads + control).
    pub bytes_sent: u64,
    /// Total frame bytes nodes → coordinator (selections + acks).
    pub bytes_received: u64,
    /// Model-sync messages sent (one per node per round).
    pub sync_messages: u64,
    /// Sync messages that were delta-encoded.
    pub delta_syncs: u64,
    /// Sync messages that fell back to full state.
    pub full_syncs: u64,
    /// Actual sync payload bytes shipped (delta or full, as sent).
    pub sync_bytes: u64,
    /// What the same syncs would have cost shipped as full state every
    /// round — the denominator of [`NetStats::delta_ratio`].
    pub full_equiv_bytes: u64,
    /// Receive deadlines that expired waiting on a node.
    pub timeouts: u64,
    /// Extra receive attempts granted after a timeout (heartbeat sent,
    /// deadline re-armed).
    pub retries: u64,
    /// Rounds where a dead node's lane range was re-run locally.
    pub failovers: u64,
    /// Nodes re-adopted after failover via a full-snapshot resync.
    pub reconnects: u64,
}

impl NetStats {
    /// Shipped sync bytes over always-full-state bytes: < 1.0 means delta
    /// encoding saved wire traffic.
    pub fn delta_ratio(&self) -> f64 {
        if self.full_equiv_bytes == 0 {
            1.0
        } else {
            self.sync_bytes as f64 / self.full_equiv_bytes as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_ratio_handles_empty_and_savings() {
        assert_eq!(NetStats::default().delta_ratio(), 1.0);
        let s = NetStats { sync_bytes: 250, full_equiv_bytes: 1000, ..Default::default() };
        assert!((s.delta_ratio() - 0.25).abs() < 1e-12);
    }
}
