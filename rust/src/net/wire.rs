//! Little-endian byte packing shared by the protocol and the delta
//! codecs. The vendor set is fixed (no serde), so encoding is explicit:
//! writers append to a `Vec<u8>`, [`Reader`] walks a received payload
//! with bounds checks that turn truncation into errors instead of
//! panics.

use anyhow::Result;

pub(crate) fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}

pub(crate) fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Overflow-checked u32 length prefix. Every count that crosses the wire
/// goes through here: a length that does not fit the prefix is an error
/// on the *encode* side, mirroring how [`Reader`] turns truncation into
/// errors on the decode side — never a silent `as u32` wraparound that
/// the peer would misparse.
pub(crate) fn put_len(buf: &mut Vec<u8>, n: usize) -> Result<()> {
    let v = u32::try_from(n)
        .map_err(|_| anyhow::anyhow!("length {n} overflows the u32 wire prefix"))?;
    put_u32(buf, v);
    Ok(())
}

/// Length-prefixed f32 slice (u32 count, then raw values). Errors if the
/// count overflows the prefix.
pub(crate) fn put_f32s(buf: &mut Vec<u8>, vs: &[f32]) -> Result<()> {
    put_len(buf, vs.len())?;
    buf.reserve(vs.len() * 4);
    for &v in vs {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    Ok(())
}

/// Bounds-checked cursor over a received payload.
pub(crate) struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len());
        let end = end.ok_or_else(|| {
            anyhow::anyhow!(
                "truncated message: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len()
            )
        })?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read a length-prefixed f32 slice written by [`put_f32s`].
    pub(crate) fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        self.f32s_exact(n)
    }

    /// Read exactly `n` raw f32 values (no length prefix).
    pub(crate) fn f32s_exact(&mut self, n: usize) -> Result<Vec<f32>> {
        let len = n.checked_mul(4).ok_or_else(|| anyhow::anyhow!("f32 run overflow"))?;
        let bytes = self.take(len)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Read exactly `n` raw bytes.
    pub(crate) fn bytes(&mut self, n: usize) -> Result<Vec<u8>> {
        Ok(self.take(n)?.to_vec())
    }

    /// Remaining unread bytes (0 once a message is fully consumed).
    pub(crate) fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_scalar_kinds() {
        let mut buf = Vec::new();
        put_u8(&mut buf, 7);
        put_u32(&mut buf, 0xDEAD_BEEF);
        put_u64(&mut buf, u64::MAX - 3);
        put_f32(&mut buf, -0.125);
        put_f64(&mut buf, 2.5e-300);
        put_f32s(&mut buf, &[1.0, f32::MIN_POSITIVE, -0.0]).unwrap();
        let mut r = Reader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f32().unwrap().to_bits(), (-0.125f32).to_bits());
        assert_eq!(r.f64().unwrap().to_bits(), (2.5e-300f64).to_bits());
        let vs = r.f32s().unwrap();
        assert_eq!(vs.len(), 3);
        assert_eq!(vs[2].to_bits(), (-0.0f32).to_bits(), "bit-exact: -0.0 survives");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 10); // claims 10 f32s, delivers none
        let mut r = Reader::new(&buf);
        assert!(r.f32s().is_err());
        let mut r2 = Reader::new(&[1, 2]);
        assert!(r2.u32().is_err());
    }

    // Mirror of `truncation_is_an_error_not_a_panic` for the encode side:
    // a count too large for the u32 prefix must refuse to encode instead
    // of silently wrapping to a small number the peer would misparse.
    #[test]
    fn length_overflow_is_an_error_not_a_silent_cast() {
        let mut buf = Vec::new();
        assert!(put_len(&mut buf, u32::MAX as usize).is_ok(), "the max prefix still fits");
        let over = u32::MAX as u64 + 1;
        if let Ok(n) = usize::try_from(over) {
            let before = buf.len();
            let err = put_len(&mut buf, n).unwrap_err();
            assert!(err.to_string().contains("overflows"), "{err}");
            assert_eq!(buf.len(), before, "a failed prefix must not leave partial bytes");
        }
    }
}
