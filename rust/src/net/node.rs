//! The sift-node serve loop — one process's worth of remote lanes.
//!
//! A node process receives [`InitMsg`], rebuilds its lane range
//! `[lane_lo, lane_hi)` with the **same** constructor the in-process
//! coordinator uses ([`make_lane`]: node-seeded stream, node-seeded
//! sifter RNG, preallocated shard buffers), and then serves rounds: apply
//! the model sync, draw each lane's shard locally, sift on the PR 3
//! execution pool, reply with the per-lane selections in lane order.
//! Example data never crosses the wire — determinism regenerates it.
//!
//! The node owning lane 0 additionally skips the warmstart head of lane
//! 0's stream (`InitMsg::skip`): the coordinator consumed those examples
//! locally during its warmstart phase, so the remote stream must resume
//! exactly where the in-process one would have.
//!
//! The replica learner only ever *scores* — its update machinery is never
//! touched; [`ModelCodec::apply`] installs the coordinator's scoring view
//! with the source model's exact bits each round.

use super::delta::ModelCodec;
use super::proto::{ByeMsg, Msg, ReadyMsg, SiftMsg, TaskKind, PROTO_VERSION};
use super::transport::Channel;
use crate::coordinator::backend::{NodeJob, SiftBackend};
use crate::coordinator::sync::make_lane;
use crate::data::{StreamConfig, DIM};
use crate::exec::PoolStats;
use crate::learner::{Learner, SiftScorer};
use anyhow::{Context, Result};

pub(crate) fn send_msg(chan: &mut dyn Channel, msg: &Msg) -> Result<()> {
    let _sp = crate::obs_span!("net.send");
    chan.send(&msg.encode()?)
}

pub(crate) fn recv_msg(chan: &mut dyn Channel) -> Result<Msg> {
    let _sp = crate::obs_span!("net.recv");
    Msg::decode(&chan.recv()?)
}

/// What one node process did over its lifetime, for logging on the node
/// side (the coordinator gets the same pool counters via [`Msg::Bye`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SiftNodeReport {
    pub node_index: u32,
    /// Lanes this process hosted.
    pub lanes: usize,
    pub rounds: u64,
    pub pool: PoolStats,
}

/// Serve one sift node over `chan` until the coordinator says shutdown.
///
/// `replica` is a freshly constructed learner of the run's type — its
/// scoring view is overwritten by the first (full) sync before any shard
/// is scored. `task` and `fingerprint` are this process's own idea of the
/// run configuration; the init handshake cross-checks them against the
/// coordinator's so a mis-launched node fails fast with an actionable
/// error instead of silently diverging.
#[allow(clippy::too_many_arguments)]
pub fn serve_sift_node<L: Learner>(
    chan: &mut dyn Channel,
    replica: &mut L,
    codec: &mut dyn ModelCodec<L>,
    scorer: &dyn SiftScorer<L>,
    backend: &dyn SiftBackend,
    stream_cfg: &StreamConfig,
    task: TaskKind,
    fingerprint: u64,
) -> Result<SiftNodeReport> {
    let init = match recv_msg(chan).context("waiting for init")? {
        Msg::Init(m) => m,
        other => anyhow::bail!("expected init message, got {other:?}"),
    };
    anyhow::ensure!(
        init.version == PROTO_VERSION,
        "protocol version mismatch: coordinator speaks v{}, this node v{PROTO_VERSION} \
         — rebuild both sides from the same source",
        init.version
    );
    anyhow::ensure!(
        init.task == task,
        "task mismatch: coordinator is running {} but this node was launched for {} \
         — restart the node with the matching subcommand",
        init.task.name(),
        task.name()
    );
    anyhow::ensure!(
        init.fingerprint == fingerprint,
        "config fingerprint mismatch (coordinator {:#x}, node {:#x}) — both processes \
         must be launched with identical experiment flags",
        init.fingerprint,
        fingerprint
    );
    anyhow::ensure!(
        init.lane_lo < init.lane_hi && init.lane_hi <= init.k,
        "bad lane range [{}, {}) for k={}",
        init.lane_lo,
        init.lane_hi,
        init.k
    );
    anyhow::ensure!(init.shard >= 1, "shard size must be >= 1");

    let cfg = stream_cfg.clone().with_seed(init.stream_seed);
    let shard = init.shard as usize;
    let mut lanes: Vec<_> = (init.lane_lo..init.lane_hi)
        .map(|n| make_lane(&cfg, &init.sifter, n as usize, shard))
        .collect();
    // Lane 0's stream resumes after the coordinator's warmstart head.
    if init.lane_lo == 0 && init.skip > 0 {
        let mut x = vec![0.0f32; DIM];
        for _ in 0..init.skip {
            lanes[0].stream.next_into(&mut x);
        }
    }
    let needs_scores = init.sifter.needs_scores();
    send_msg(
        chan,
        &Msg::Ready(ReadyMsg { node_index: init.node_index, lanes: lanes.len() as u32 }),
    )?;

    let mut rounds = 0u64;
    let mut last_round = 0u64;
    let mut outcome: Option<Result<PoolStats>> = None;
    backend.with_session(&mut |session| {
        outcome = Some((|| loop {
            match recv_msg(chan)? {
                Msg::Ping(seq) => {
                    // Coordinator liveness probe (it may be deciding
                    // whether to fail our lanes over) — echo and keep
                    // waiting for the round.
                    send_msg(chan, &Msg::Pong(seq))?;
                }
                Msg::Round(rm) => {
                    let node_id = init.node_index as i64;
                    let _sp_round =
                        crate::obs_span!("round", round = rm.round as i64, node = node_id);
                    // Rounds we never saw (a disconnect window the
                    // coordinator failed over) consumed our lanes'
                    // streams and sifter coins on the coordinator —
                    // replay the draws locally so both sides' lane state
                    // agrees bit for bit before this round's shard.
                    if rm.round > last_round + 1 {
                        let gap = (rm.round - last_round - 1) as usize;
                        for lane in lanes.iter_mut() {
                            lane.fast_forward(gap * shard);
                        }
                    }
                    last_round = rm.round;
                    {
                        let _sp =
                            crate::obs_span!("sync", round = rm.round as i64, node = node_id);
                        codec.apply(replica, &rm.sync).context("applying model sync")?;
                    }
                    // Draw shards locally — generation is off every clock,
                    // identical to the in-process loops.
                    for lane in lanes.iter_mut() {
                        lane.stream.next_batch_into(&mut lane.xs, &mut lane.ys);
                    }
                    let round = rm.round;
                    let n_phase = rm.n_phase;
                    let frozen: &L = replica;
                    let jobs: Vec<NodeJob<'_>> = lanes
                        .iter_mut()
                        .map(|lane| {
                            let job: NodeJob<'_> = Box::new(move |worker| {
                                let _sp = crate::obs_span!(
                                    "sift",
                                    node = node_id,
                                    round = round as i64,
                                    worker = worker as i64
                                );
                                lane.sift_round(
                                    frozen,
                                    scorer,
                                    shard,
                                    n_phase,
                                    needs_scores,
                                    worker,
                                )
                            });
                            job
                        })
                        .collect();
                    let results = session.run_round(jobs);
                    rounds += 1;
                    send_msg(chan, &Msg::Sift(SiftMsg { round, lanes: results }))?;
                }
                Msg::Shutdown => {
                    let stats = session.stats();
                    send_msg(chan, &Msg::Bye(ByeMsg { pool: stats }))?;
                    return Ok(stats);
                }
                other => anyhow::bail!("unexpected message in round loop: {other:?}"),
            }
        })());
    });
    let pool = outcome.expect("backend never ran the session body")?;
    Ok(SiftNodeReport { node_index: init.node_index, lanes: lanes.len(), rounds, pool })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::active::SifterSpec;
    use crate::coordinator::backend::SerialBackend;
    use crate::data::DIM;
    use crate::learner::NativeScorer;
    use crate::net::delta::MlpDenseCodec;
    use crate::net::proto::InitMsg;
    use crate::net::transport::InProcTransport;
    use crate::net::Transport;
    use crate::nn::{AdaGradMlp, MlpConfig};

    fn test_init() -> InitMsg {
        InitMsg {
            version: PROTO_VERSION,
            task: TaskKind::Nn,
            fingerprint: 0xABCD,
            node_index: 0,
            lane_lo: 0,
            lane_hi: 1,
            k: 1,
            shard: 4,
            skip: 0,
            stream_seed: StreamConfig::nn_task().seed,
            sifter: SifterSpec::Passive,
        }
    }

    fn serve_with(init: InitMsg, fingerprint: u64, task: TaskKind) -> Result<SiftNodeReport> {
        let (mut hub, mut chans) = InProcTransport::pair(1);
        let handle = std::thread::spawn(move || {
            let mut replica = AdaGradMlp::new(MlpConfig::paper(DIM));
            let mut codec = MlpDenseCodec::new();
            let mut chan = chans.remove(0);
            serve_sift_node(
                &mut chan,
                &mut replica,
                &mut codec,
                &NativeScorer,
                &SerialBackend,
                &StreamConfig::nn_task(),
                task,
                fingerprint,
            )
        });
        hub.send_to(0, &Msg::Init(init).encode().unwrap()).unwrap();
        // On success the node acks with Ready and waits for rounds; close
        // the hub (drop) to let a successful server error out of recv —
        // but first give mismatch cases their immediate error. Send a
        // shutdown so the happy path terminates cleanly.
        if let Ok(bytes) = hub.recv_from(0) {
            if matches!(Msg::decode(&bytes), Ok(Msg::Ready(_))) {
                hub.send_to(0, &Msg::Shutdown.encode().unwrap()).unwrap();
                let _ = hub.recv_from(0); // Bye
            }
        }
        drop(hub);
        handle.join().expect("node thread panicked")
    }

    #[test]
    fn node_serves_handshake_and_shutdown() {
        let report = serve_with(test_init(), 0xABCD, TaskKind::Nn).unwrap();
        assert_eq!(report.node_index, 0);
        assert_eq!(report.lanes, 1);
        assert_eq!(report.rounds, 0);
    }

    #[test]
    fn node_answers_heartbeats_between_rounds() {
        let (mut hub, mut chans) = InProcTransport::pair(1);
        let handle = std::thread::spawn(move || {
            let mut replica = AdaGradMlp::new(MlpConfig::paper(DIM));
            let mut codec = MlpDenseCodec::new();
            let mut chan = chans.remove(0);
            serve_sift_node(
                &mut chan,
                &mut replica,
                &mut codec,
                &NativeScorer,
                &SerialBackend,
                &StreamConfig::nn_task(),
                TaskKind::Nn,
                0xABCD,
            )
        });
        hub.send_to(0, &Msg::Init(test_init()).encode().unwrap()).unwrap();
        assert!(matches!(Msg::decode(&hub.recv_from(0).unwrap()).unwrap(), Msg::Ready(_)));
        for seq in [7u64, 8] {
            hub.send_to(0, &Msg::Ping(seq).encode().unwrap()).unwrap();
            match Msg::decode(&hub.recv_from(0).unwrap()).unwrap() {
                Msg::Pong(got) => assert_eq!(got, seq),
                other => panic!("expected pong, got {other:?}"),
            }
        }
        hub.send_to(0, &Msg::Shutdown.encode().unwrap()).unwrap();
        let _ = hub.recv_from(0); // Bye
        drop(hub);
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn node_rejects_version_task_and_fingerprint_mismatches() {
        let mut bad_version = test_init();
        bad_version.version = PROTO_VERSION + 1;
        let err = serve_with(bad_version, 0xABCD, TaskKind::Nn).unwrap_err();
        assert!(err.to_string().contains("protocol version"), "{err}");

        let err = serve_with(test_init(), 0xABCD, TaskKind::Svm).unwrap_err();
        assert!(err.to_string().contains("task mismatch"), "{err}");

        let err = serve_with(test_init(), 0xBEEF, TaskKind::Nn).unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");

        let mut bad_range = test_init();
        bad_range.lane_hi = 0;
        let err = serve_with(bad_range, 0xABCD, TaskKind::Nn).unwrap_err();
        assert!(err.to_string().contains("lane range"), "{err}");
    }
}
