//! Epoch-versioned model-sync codecs.
//!
//! A distributed sift node never updates — it only needs the *scoring
//! view* of the coordinator's model, refreshed once per round. Shipping
//! the whole view every round is wasteful for LASVM: the support set
//! accrues (mostly) monotonically while alphas move in place, so once a
//! replica has seen an SV's row bytes it only ever needs that SV's new
//! alpha again. [`SvmDeltaCodec`] exploits exactly that:
//!
//! * the encoder keeps a **slot table** of every SV row it has ever
//!   shipped (hash of the row's exact f32 bits → slot id);
//! * each epoch's delta message is the full active list *by reference*:
//!   bias, then one entry per live SV in snapshot order — a 9-byte
//!   `(slot, alpha)` pair for known rows, or the full row for new ones;
//! * whenever the delta would not beat the full snapshot (first sync,
//!   or a support set that churned wholesale), the codec **falls back to
//!   full state** and resets the slot table to match — the decoder's
//!   table is rebuilt identically, so slot ids never drift.
//!
//! Because every message carries the complete active list (not a diff of
//! positions), apply handles alpha→0 removals, resurrected SVs and the
//! solver's `compact()` reorderings for free, and the replica's snapshot
//! ends up in exactly the source's order with exactly the source's bits
//! — the precondition for bit-identical tiled scoring on the node.
//!
//! [`MlpDenseCodec`] gives the MLP the same surface: dense weight state
//! diffed index-by-index with the identical full-state fallback. AdaGrad
//! touches every parameter on every update, so in practice the fallback
//! fires and MLP sync ships full dense state — the [`NetStats`]
//! delta-vs-full ratio reports that honestly instead of pretending.
//!
//! Messages are versioned by epoch: apply is idempotent per epoch
//! (re-applying an already-applied epoch is a no-op) and rejects gaps,
//! so a replica can never silently score with a half-applied model.
//!
//! [`NetStats`]: super::NetStats

use super::wire::{put_f32, put_len, put_u32, put_u8, Reader};
use crate::learner::Learner;
use crate::nn::AdaGradMlp;
use crate::svm::{lasvm::LaSvm, Kernel};
use anyhow::Result;
use std::collections::HashMap;

/// One epoch's model sync, as shipped inside a round message.
#[derive(Debug, Clone, PartialEq)]
pub struct SyncMessage {
    /// Monotonically increasing model version (one per round).
    pub epoch: u64,
    /// True when the payload is full state (fallback), false for a delta.
    pub full: bool,
    pub payload: Vec<u8>,
}

/// Encoder/decoder pair for one learner type. One instance per *role*:
/// the coordinator owns an encoding instance, each node a decoding one —
/// the codec's internal table tracks the peer's state, and mixing roles
/// on one instance would corrupt it.
pub trait ModelCodec<L: ?Sized>: Send {
    /// Coordinator side: encode the model's scoring view at `epoch`.
    /// Epochs must be passed in strictly increasing, gap-free order.
    /// Errors if a length prefix in the payload would overflow u32.
    fn encode(&mut self, epoch: u64, model: &L) -> Result<SyncMessage>;

    /// Coordinator side: force a full-state snapshot at `epoch`,
    /// resetting the delta baseline to match. Used to re-adopt a node
    /// that missed rounds (its decoder accepts full state at any forward
    /// epoch); the reset keeps *every* decoder's table in lockstep, so
    /// it must be broadcast to all live nodes, not sent point-to-point.
    fn encode_full(&mut self, epoch: u64, model: &L) -> Result<SyncMessage>;

    /// Bytes the last [`ModelCodec::encode`] would have cost as full
    /// state — the denominator of the delta-vs-full telemetry.
    fn last_full_bytes(&self) -> u64;

    /// Node side: install `msg` into the replica. Idempotent per epoch;
    /// rejects epoch gaps and deltas with no prior full state.
    fn apply(&mut self, replica: &mut L, msg: &SyncMessage) -> Result<()>;
}

/// FNV-1a over the exact f32 bit patterns of a row.
fn hash_row(row: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &v in row {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

fn rows_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Shared epoch bookkeeping for the decoder side of both codecs.
#[derive(Debug, Clone, Copy, Default)]
struct EpochGuard {
    applied: Option<u64>,
}

enum EpochAction {
    Skip,
    Apply,
}

impl EpochGuard {
    /// Idempotency and ordering: already-applied epochs are skipped,
    /// gapped deltas and deltas-before-full are errors, full state is
    /// accepted at any forward epoch.
    fn check(&self, msg: &SyncMessage) -> Result<EpochAction> {
        if let Some(prev) = self.applied {
            if msg.epoch <= prev {
                return Ok(EpochAction::Skip);
            }
            if !msg.full && msg.epoch != prev + 1 {
                anyhow::bail!(
                    "delta sync epoch gap: have epoch {prev}, got delta for {}",
                    msg.epoch
                );
            }
        } else if !msg.full {
            anyhow::bail!("delta sync before any full state (epoch {})", msg.epoch);
        }
        Ok(EpochAction::Apply)
    }
}

const ENTRY_REF: u8 = 0;
const ENTRY_NEW: u8 = 1;

/// Slot-table delta codec for [`LaSvm`] scoring views; see the module
/// docs for the scheme.
pub struct SvmDeltaCodec {
    dim: usize,
    /// Every row ever shipped, slot-major (`slot * dim ..`). Grows with
    /// the distinct-SV set — the monotone accrual the paper relies on.
    rows: Vec<f32>,
    /// Row-bits hash → candidate slots (encoder lookup; collisions are
    /// resolved by exact bit comparison).
    index: HashMap<u64, Vec<u32>>,
    guard: EpochGuard,
    last_full: u64,
}

impl SvmDeltaCodec {
    pub fn new(dim: usize) -> Self {
        assert!(dim >= 1);
        SvmDeltaCodec {
            dim,
            rows: Vec::new(),
            index: HashMap::new(),
            guard: EpochGuard::default(),
            last_full: 0,
        }
    }

    fn n_slots(&self) -> usize {
        self.rows.len() / self.dim
    }

    fn slot_row(&self, slot: u32) -> &[f32] {
        let s = slot as usize * self.dim;
        &self.rows[s..s + self.dim]
    }

    /// Find the slot holding exactly `row`'s bits, if any.
    fn lookup(&self, h: u64, row: &[f32]) -> Option<u32> {
        self.index
            .get(&h)?
            .iter()
            .copied()
            .find(|&s| rows_equal(self.slot_row(s), row))
    }

    /// Append `row` as a fresh slot.
    fn alloc(&mut self, h: u64, row: &[f32]) -> u32 {
        let slot = self.n_slots() as u32;
        self.rows.extend_from_slice(row);
        self.index.entry(h).or_default().push(slot);
        slot
    }

    /// Reset the slot table to exactly the given view (what a decoder
    /// does on receiving full state — both sides must stay in lockstep).
    fn reset_to_view(&mut self, pts: &[f32]) {
        self.rows.clear();
        self.index.clear();
        for row in pts.chunks_exact(self.dim) {
            self.alloc(hash_row(row), row);
        }
    }

    fn full_payload(n: usize, bias: f32, pts: &[f32], alpha: &[f32]) -> Result<Vec<u8>> {
        let mut payload = Vec::with_capacity(8 + (pts.len() + alpha.len()) * 4);
        put_len(&mut payload, n)?;
        put_f32(&mut payload, bias);
        for &v in pts {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        for &v in alpha {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        Ok(payload)
    }
}

impl<K: Kernel> ModelCodec<LaSvm<K>> for SvmDeltaCodec {
    fn encode(&mut self, epoch: u64, model: &LaSvm<K>) -> Result<SyncMessage> {
        assert_eq!(model.dim(), self.dim, "codec dim mismatch");
        let (pts, alpha) = model.export_support();
        let bias = model.bias();
        let n = alpha.len();
        let full_bytes = 8 + n * (self.dim + 1) * 4;
        self.last_full = full_bytes as u64;

        // Build the delta tentatively; roll the slot table back (via
        // reset) if full state wins, so encoder and decoder tables can
        // never diverge.
        let mut delta = Vec::with_capacity(8 + n * 9);
        put_len(&mut delta, n)?;
        put_f32(&mut delta, bias);
        for i in 0..n {
            let row = &pts[i * self.dim..(i + 1) * self.dim];
            let h = hash_row(row);
            match self.lookup(h, row) {
                Some(slot) => {
                    put_u8(&mut delta, ENTRY_REF);
                    put_u32(&mut delta, slot);
                }
                None => {
                    // Allocated now, in entry order — the decoder
                    // allocates in the same order, so ids agree.
                    self.alloc(h, row);
                    put_u8(&mut delta, ENTRY_NEW);
                    for &v in row {
                        delta.extend_from_slice(&v.to_le_bytes());
                    }
                }
            }
            put_f32(&mut delta, alpha[i]);
        }

        if delta.len() >= full_bytes {
            self.reset_to_view(&pts);
            Ok(SyncMessage { epoch, full: true, payload: Self::full_payload(n, bias, &pts, &alpha)? })
        } else {
            Ok(SyncMessage { epoch, full: false, payload: delta })
        }
    }

    fn encode_full(&mut self, epoch: u64, model: &LaSvm<K>) -> Result<SyncMessage> {
        assert_eq!(model.dim(), self.dim, "codec dim mismatch");
        let (pts, alpha) = model.export_support();
        let bias = model.bias();
        let n = alpha.len();
        self.last_full = (8 + n * (self.dim + 1) * 4) as u64;
        self.reset_to_view(&pts);
        Ok(SyncMessage { epoch, full: true, payload: Self::full_payload(n, bias, &pts, &alpha)? })
    }

    fn last_full_bytes(&self) -> u64 {
        self.last_full
    }

    fn apply(&mut self, replica: &mut LaSvm<K>, msg: &SyncMessage) -> Result<()> {
        assert_eq!(replica.dim(), self.dim, "codec dim mismatch");
        if matches!(self.guard.check(msg)?, EpochAction::Skip) {
            return Ok(());
        }
        let mut r = Reader::new(&msg.payload);
        let n = r.u32()? as usize;
        let bias = r.f32()?;
        let (pts, alpha) = if msg.full {
            let pts = r.f32s_exact(n * self.dim)?;
            let alpha = r.f32s_exact(n)?;
            self.reset_to_view(&pts);
            (pts, alpha)
        } else {
            // Every delta entry costs >= 9 payload bytes (tag + slot
            // ref + alpha), so an entry count the remaining bytes cannot
            // cover is garbage — reject it before sizing buffers for it.
            anyhow::ensure!(
                n <= r.remaining() / 9,
                "delta claims {n} entries but only {} bytes remain",
                r.remaining()
            );
            let mut pts = Vec::with_capacity(n * self.dim);
            let mut alpha = Vec::with_capacity(n);
            for _ in 0..n {
                match r.u8()? {
                    ENTRY_REF => {
                        let slot = r.u32()?;
                        anyhow::ensure!(
                            (slot as usize) < self.n_slots(),
                            "delta references unknown slot {slot} (have {})",
                            self.n_slots()
                        );
                        pts.extend_from_slice(self.slot_row(slot));
                    }
                    ENTRY_NEW => {
                        let row = r.f32s_exact(self.dim)?;
                        self.alloc(hash_row(&row), &row);
                        pts.extend_from_slice(&row);
                    }
                    other => anyhow::bail!("unknown delta entry tag {other}"),
                }
                alpha.push(r.f32()?);
            }
            (pts, alpha)
        };
        anyhow::ensure!(r.remaining() == 0, "trailing bytes in sync payload");
        replica.install_scoring_view(&pts, &alpha, bias);
        self.guard.applied = Some(msg.epoch);
        Ok(())
    }
}

/// Dense weight-state codec for [`AdaGradMlp`]: per-epoch sparse
/// index/value diffs over the flat `(w1, b1, w2, b2)` state, with the
/// same full-state fallback as the SVM codec. AdaGrad moves every
/// parameter every update, so the fallback fires on real runs — kept as
/// a codec (rather than always-full) so the threshold machinery and the
/// telemetry treat both learners uniformly.
pub struct MlpDenseCodec {
    /// Mirror of the peer's flat state; empty until the first sync.
    state: Vec<f32>,
    /// (w1 len, b1 len, w2 len); the final element of `state` is b2.
    dims: Option<(usize, usize, usize)>,
    guard: EpochGuard,
    last_full: u64,
}

impl MlpDenseCodec {
    pub fn new() -> Self {
        MlpDenseCodec { state: Vec::new(), dims: None, guard: EpochGuard::default(), last_full: 0 }
    }

    fn flat_state(model: &AdaGradMlp) -> (Vec<f32>, (usize, usize, usize)) {
        let (w1, b1, w2, b2) = model.sync_weights();
        let mut flat = Vec::with_capacity(w1.len() + b1.len() + w2.len() + 1);
        flat.extend_from_slice(w1);
        flat.extend_from_slice(b1);
        flat.extend_from_slice(w2);
        flat.push(b2);
        (flat, (w1.len(), b1.len(), w2.len()))
    }

    fn put_dims(payload: &mut Vec<u8>, dims: (usize, usize, usize)) -> Result<()> {
        put_len(payload, dims.0)?;
        put_len(payload, dims.1)?;
        put_len(payload, dims.2)
    }

    fn install(&self, replica: &mut AdaGradMlp) -> Result<()> {
        // Reachable on a protocol-order violation (a delta arriving at a
        // fresh decoder) — must be a typed error, not a panic: the peer
        // chooses what arrives first.
        let (l1, l2, l3) = self
            .dims
            .ok_or_else(|| anyhow::anyhow!("mlp sync: delta before any full state (no dims)"))?;
        anyhow::ensure!(self.state.len() == l1 + l2 + l3 + 1, "mlp sync state length mismatch");
        // The dims triple is peer-controlled: a corrupt split that keeps
        // the same total would pass the length check above but trip the
        // model's shape asserts — refuse it here as a typed error.
        let (rw1, rb1, rw2, _) = replica.sync_weights();
        anyhow::ensure!(
            rw1.len() == l1 && rb1.len() == l2 && rw2.len() == l3,
            "mlp sync dims {l1}/{l2}/{l3} do not match the replica ({}/{}/{})",
            rw1.len(),
            rb1.len(),
            rw2.len()
        );
        let (w1, rest) = self.state.split_at(l1);
        let (b1, rest) = rest.split_at(l2);
        let (w2, b2) = rest.split_at(l3);
        replica.install_sync_weights(w1, b1, w2, b2[0]);
        Ok(())
    }
}

impl Default for MlpDenseCodec {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelCodec<AdaGradMlp> for MlpDenseCodec {
    fn encode(&mut self, epoch: u64, model: &AdaGradMlp) -> Result<SyncMessage> {
        let (flat, dims) = Self::flat_state(model);
        let full_bytes = 12 + flat.len() * 4;
        self.last_full = full_bytes as u64;

        let make_full = |flat: &[f32]| -> Result<Vec<u8>> {
            let mut payload = Vec::with_capacity(full_bytes);
            Self::put_dims(&mut payload, dims)?;
            for &v in flat {
                payload.extend_from_slice(&v.to_le_bytes());
            }
            Ok(payload)
        };

        if self.dims != Some(dims) || self.state.len() != flat.len() {
            let payload = make_full(&flat)?;
            self.state = flat;
            self.dims = Some(dims);
            return Ok(SyncMessage { epoch, full: true, payload });
        }

        let changed: Vec<u32> = flat
            .iter()
            .zip(&self.state)
            .enumerate()
            .filter(|(_, (a, b))| a.to_bits() != b.to_bits())
            .map(|(i, _)| i as u32)
            .collect();
        let delta_bytes = 16 + changed.len() * 8;
        if delta_bytes >= full_bytes {
            let payload = make_full(&flat)?;
            self.state = flat;
            return Ok(SyncMessage { epoch, full: true, payload });
        }
        let mut payload = Vec::with_capacity(delta_bytes);
        Self::put_dims(&mut payload, dims)?;
        put_len(&mut payload, changed.len())?;
        for &i in &changed {
            put_u32(&mut payload, i);
            put_f32(&mut payload, flat[i as usize]);
        }
        self.state = flat;
        Ok(SyncMessage { epoch, full: false, payload })
    }

    fn encode_full(&mut self, epoch: u64, model: &AdaGradMlp) -> Result<SyncMessage> {
        let (flat, dims) = Self::flat_state(model);
        let full_bytes = 12 + flat.len() * 4;
        self.last_full = full_bytes as u64;
        let mut payload = Vec::with_capacity(full_bytes);
        Self::put_dims(&mut payload, dims)?;
        for &v in &flat {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        self.state = flat;
        self.dims = Some(dims);
        Ok(SyncMessage { epoch, full: true, payload })
    }

    fn last_full_bytes(&self) -> u64 {
        self.last_full
    }

    fn apply(&mut self, replica: &mut AdaGradMlp, msg: &SyncMessage) -> Result<()> {
        if matches!(self.guard.check(msg)?, EpochAction::Skip) {
            return Ok(());
        }
        let mut r = Reader::new(&msg.payload);
        let dims = (r.u32()? as usize, r.u32()? as usize, r.u32()? as usize);
        let total = dims.0 + dims.1 + dims.2 + 1;
        if msg.full {
            self.state = r.f32s_exact(total)?;
            self.dims = Some(dims);
        } else {
            anyhow::ensure!(
                self.dims == Some(dims) && self.state.len() == total,
                "mlp delta against mismatched state"
            );
            let n = r.u32()? as usize;
            for _ in 0..n {
                let i = r.u32()? as usize;
                let v = r.f32()?;
                anyhow::ensure!(i < total, "mlp delta index {i} out of range {total}");
                self.state[i] = v;
            }
        }
        anyhow::ensure!(r.remaining() == 0, "trailing bytes in sync payload");
        self.install(replica)?;
        self.guard.applied = Some(msg.epoch);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{ExampleStream, StreamConfig, DIM};
    use crate::nn::MlpConfig;
    use crate::svm::{LaSvmConfig, RbfKernel};

    fn trained_svm(n: usize) -> LaSvm<RbfKernel> {
        let cfg = StreamConfig::svm_task();
        let mut stream = ExampleStream::for_node(&cfg, 0);
        let mut svm = LaSvm::new(RbfKernel::paper(), DIM, LaSvmConfig::default());
        let mut x = vec![0.0f32; DIM];
        for _ in 0..n {
            let y = stream.next_into(&mut x);
            svm.update(&x, y, 1.0);
        }
        svm
    }

    fn probe_scores<L: Learner>(l: &L) -> Vec<u32> {
        let mut probe = ExampleStream::for_node(&StreamConfig::svm_task(), 77);
        let mut x = vec![0.0f32; DIM];
        (0..8)
            .map(|_| {
                probe.next_into(&mut x);
                l.score(&x).to_bits()
            })
            .collect()
    }

    #[test]
    fn svm_first_sync_is_full_then_deltas_shrink() {
        let mut enc = SvmDeltaCodec::new(DIM);
        let mut dec = SvmDeltaCodec::new(DIM);
        let mut replica = LaSvm::new(RbfKernel::paper(), DIM, LaSvmConfig::default());

        let svm = trained_svm(120);
        let m1 = enc.encode(1, &svm).unwrap();
        assert!(m1.full, "an all-new support set cannot win as a delta");
        dec.apply(&mut replica, &m1).unwrap();
        assert_eq!(probe_scores(&replica), probe_scores(&svm), "replica scores bit-identical");
        assert_eq!(replica.n_support(), svm.n_support());

        // Grow the model a little: most SVs are now known rows.
        let mut svm2 = svm;
        let mut stream = ExampleStream::for_node(&StreamConfig::svm_task(), 1);
        let mut x = vec![0.0f32; DIM];
        for _ in 0..30 {
            let y = stream.next_into(&mut x);
            svm2.update(&x, y, 1.0);
        }
        let m2 = enc.encode(2, &svm2).unwrap();
        assert!(!m2.full, "incremental growth must delta-encode");
        assert!(
            (m2.payload.len() as u64) < enc.last_full_bytes() / 4,
            "delta {} vs full {}",
            m2.payload.len(),
            enc.last_full_bytes()
        );
        dec.apply(&mut replica, &m2).unwrap();
        assert_eq!(probe_scores(&replica), probe_scores(&svm2));
    }

    #[test]
    fn svm_apply_is_idempotent_and_rejects_gaps() {
        let mut enc = SvmDeltaCodec::new(DIM);
        let mut dec = SvmDeltaCodec::new(DIM);
        let mut replica = LaSvm::new(RbfKernel::paper(), DIM, LaSvmConfig::default());
        let svm = trained_svm(60);
        let m1 = enc.encode(1, &svm).unwrap();
        dec.apply(&mut replica, &m1).unwrap();
        let before = probe_scores(&replica);
        dec.apply(&mut replica, &m1).unwrap(); // idempotent re-apply
        assert_eq!(probe_scores(&replica), before);

        let mut svm2 = trained_svm(90);
        svm2.update(&vec![0.5; DIM], 1.0, 1.0);
        let _m2 = enc.encode(2, &svm2).unwrap();
        let m3 = enc.encode(3, &svm2).unwrap();
        if !m3.full {
            // Skipping epoch 2 then applying 3 as a delta must fail.
            assert!(dec.apply(&mut replica, &m3).is_err());
        }
        // A fresh decoder refuses a delta with no prior full state.
        let mut fresh = SvmDeltaCodec::new(DIM);
        let delta = SyncMessage { epoch: 5, full: false, payload: vec![0, 0, 0, 0, 0, 0, 0, 0] };
        assert!(fresh.apply(&mut replica, &delta).is_err());
    }

    #[test]
    fn encode_full_readopts_a_lagging_decoder_without_desyncing_others() {
        let mut enc = SvmDeltaCodec::new(DIM);
        let mut fresh_dec = SvmDeltaCodec::new(DIM);
        let mut lagging_dec = SvmDeltaCodec::new(DIM);
        let mut fresh = LaSvm::new(RbfKernel::paper(), DIM, LaSvmConfig::default());
        let mut lagging = LaSvm::new(RbfKernel::paper(), DIM, LaSvmConfig::default());

        // Both decoders see epoch 1; only `fresh_dec` sees epochs 2-3.
        let mut svm = trained_svm(80);
        let m1 = enc.encode(1, &svm).unwrap();
        fresh_dec.apply(&mut fresh, &m1).unwrap();
        lagging_dec.apply(&mut lagging, &m1).unwrap();
        let mut stream = ExampleStream::for_node(&StreamConfig::svm_task(), 3);
        let mut x = vec![0.0f32; DIM];
        for epoch in 2..=3u64 {
            for _ in 0..20 {
                let y = stream.next_into(&mut x);
                svm.update(&x, y, 1.0);
            }
            let m = enc.encode(epoch, &svm).unwrap();
            fresh_dec.apply(&mut fresh, &m).unwrap();
        }

        // Re-adoption: one full snapshot broadcast to BOTH decoders.
        let m4 = enc.encode_full(4, &svm).unwrap();
        assert!(m4.full);
        fresh_dec.apply(&mut fresh, &m4).unwrap();
        lagging_dec.apply(&mut lagging, &m4).unwrap();
        assert_eq!(probe_scores(&lagging), probe_scores(&svm), "lagging decoder caught up");
        assert_eq!(probe_scores(&fresh), probe_scores(&svm));

        // Deltas after the reset still apply cleanly everywhere — the
        // slot tables were rebuilt in lockstep.
        for _ in 0..20 {
            let y = stream.next_into(&mut x);
            svm.update(&x, y, 1.0);
        }
        let m5 = enc.encode(5, &svm).unwrap();
        fresh_dec.apply(&mut fresh, &m5).unwrap();
        lagging_dec.apply(&mut lagging, &m5).unwrap();
        assert_eq!(probe_scores(&lagging), probe_scores(&svm));
        assert_eq!(probe_scores(&fresh), probe_scores(&svm));
    }

    #[test]
    fn mlp_delta_at_fresh_decoder_is_a_typed_error_not_a_panic() {
        let mut dec = MlpDenseCodec::new();
        let mut replica = AdaGradMlp::new(MlpConfig::paper(DIM));
        // A structurally valid delta arriving before any full state: the
        // peer chooses the order, so this must be an Err, never a panic.
        let mut payload = Vec::new();
        put_len(&mut payload, 1).unwrap();
        put_len(&mut payload, 1).unwrap();
        put_len(&mut payload, 1).unwrap();
        put_len(&mut payload, 0).unwrap();
        let msg = SyncMessage { epoch: 2, full: false, payload };
        assert!(dec.apply(&mut replica, &msg).is_err());
        // And the raw install-without-dims path (the old panic site).
        assert!(MlpDenseCodec::new().install(&mut replica).is_err());
    }

    #[test]
    fn mlp_roundtrip_and_fallback() {
        let mut enc = MlpDenseCodec::new();
        let mut dec = MlpDenseCodec::new();
        let mut mlp = AdaGradMlp::new(MlpConfig::paper(DIM));
        let mut replica = AdaGradMlp::new(MlpConfig { seed: 999, ..MlpConfig::paper(DIM) });

        let m1 = enc.encode(1, &mlp).unwrap();
        assert!(m1.full);
        dec.apply(&mut replica, &m1).unwrap();
        assert_eq!(probe_scores(&replica), probe_scores(&mlp));

        // An AdaGrad update touches ~everything: the fallback must fire.
        let mut stream = ExampleStream::for_node(&StreamConfig::nn_task(), 0);
        let mut x = vec![0.0f32; DIM];
        for _ in 0..4 {
            let y = stream.next_into(&mut x);
            mlp.update(&x, y, 1.0);
        }
        let m2 = enc.encode(2, &mlp).unwrap();
        assert!(m2.full, "dense AdaGrad churn must fall back to full state");
        dec.apply(&mut replica, &m2).unwrap();
        assert_eq!(probe_scores(&replica), probe_scores(&mlp));

        // Unchanged model → empty delta beats full easily.
        let m3 = enc.encode(3, &mlp).unwrap();
        assert!(!m3.full);
        assert_eq!(m3.payload.len(), 16);
        dec.apply(&mut replica, &m3).unwrap();
        assert_eq!(probe_scores(&replica), probe_scores(&mlp));
    }
}
