//! Message transports: framing, channels, and the coordinator-side hub.
//!
//! Everything above this module speaks in whole byte messages. A
//! [`Channel`] is one side of a reliable, ordered message pipe; a
//! [`Transport`] is the coordinator's hub over one channel per remote
//! node process, with node-indexed request/reply and broadcast. Three
//! carriers implement the same framing:
//!
//! * [`InProcTransport`] — mpsc byte channels, the in-process sequencer
//!   path (`coordinator::broadcast`'s ordered-delivery role, carried by
//!   `std::sync::mpsc`'s FIFO guarantee). This is the carrier the
//!   bit-identity tests drive, and it makes the single-process
//!   coordinator just one [`Transport`] impl among equals;
//! * [`UdsTransport`] — Unix-domain stream sockets, the real two-process
//!   carrier on one machine;
//! * [`TcpTransport`] — loopback/LAN TCP, same framing over
//!   `TcpStream`.
//!
//! Stream carriers frame each message as a little-endian u32 length
//! prefix followed by the payload. The prefix is counted in the
//! [`NetStats`](super::NetStats) byte totals for every carrier —
//! including in-proc, where no bytes actually move — so wire telemetry
//! is comparable across carriers.

use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

/// Refuse frames above 1 GiB — anything bigger is a corrupted length
/// prefix, not a real message.
const MAX_FRAME: u32 = 1 << 30;

/// Per-frame overhead charged to the byte counters (the length prefix).
pub const FRAME_OVERHEAD: u64 = 4;

/// One side of a reliable, ordered byte-message pipe.
pub trait Channel: Send {
    /// Send one whole message.
    fn send(&mut self, msg: &[u8]) -> Result<()>;
    /// Block until the next whole message arrives.
    fn recv(&mut self) -> Result<Vec<u8>>;
}

/// The coordinator's hub: one [`Channel`] per connected node process,
/// indexed 0..nodes in accept/creation order.
pub trait Transport: Send {
    /// Carrier name for reports ("inproc", "uds", "tcp").
    fn name(&self) -> &'static str;
    /// Number of connected node processes.
    fn nodes(&self) -> usize;
    /// Send one message to node `node`.
    fn send_to(&mut self, node: usize, msg: &[u8]) -> Result<()>;
    /// Block until node `node`'s next message arrives.
    fn recv_from(&mut self, node: usize) -> Result<Vec<u8>>;
    /// Send the same message to every node, in node order.
    fn broadcast(&mut self, msg: &[u8]) -> Result<()> {
        for node in 0..self.nodes() {
            self.send_to(node, msg)?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// In-process carrier.
// ---------------------------------------------------------------------

/// One endpoint of an in-process byte pipe (a pair of mpsc queues).
pub struct InProcChannel {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

impl Channel for InProcChannel {
    fn send(&mut self, msg: &[u8]) -> Result<()> {
        self.tx
            .send(msg.to_vec())
            .map_err(|_| anyhow::anyhow!("in-proc peer disconnected"))
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("in-proc peer disconnected"))
    }
}

/// The in-process hub: node endpoints live on other threads of the same
/// process, connected by FIFO mpsc queues.
pub struct InProcTransport {
    chans: Vec<InProcChannel>,
}

impl InProcTransport {
    /// Create a hub plus `n` node endpoints. Endpoint `i` talks to hub
    /// slot `i`; hand each endpoint to one node thread.
    pub fn pair(n: usize) -> (InProcTransport, Vec<InProcChannel>) {
        assert!(n >= 1, "a transport needs at least one node");
        let mut hub = Vec::with_capacity(n);
        let mut ends = Vec::with_capacity(n);
        for _ in 0..n {
            let (to_node, node_rx) = channel();
            let (to_hub, hub_rx) = channel();
            hub.push(InProcChannel { tx: to_node, rx: hub_rx });
            ends.push(InProcChannel { tx: to_hub, rx: node_rx });
        }
        (InProcTransport { chans: hub }, ends)
    }
}

impl Transport for InProcTransport {
    fn name(&self) -> &'static str {
        "inproc"
    }

    fn nodes(&self) -> usize {
        self.chans.len()
    }

    fn send_to(&mut self, node: usize, msg: &[u8]) -> Result<()> {
        self.chans[node].send(msg)
    }

    fn recv_from(&mut self, node: usize) -> Result<Vec<u8>> {
        self.chans[node].recv()
    }
}

// ---------------------------------------------------------------------
// Stream carriers (UDS, TCP): length-prefix framing over Read + Write.
// ---------------------------------------------------------------------

/// Length-prefix framing over any byte stream.
pub struct StreamChannel<S: Read + Write + Send> {
    stream: S,
}

impl<S: Read + Write + Send> StreamChannel<S> {
    pub fn new(stream: S) -> Self {
        StreamChannel { stream }
    }
}

impl<S: Read + Write + Send> Channel for StreamChannel<S> {
    fn send(&mut self, msg: &[u8]) -> Result<()> {
        let len = u32::try_from(msg.len())
            .ok()
            .filter(|&l| l <= MAX_FRAME)
            .with_context(|| format!("frame too large: {} bytes", msg.len()))?;
        self.stream.write_all(&len.to_le_bytes()).context("writing frame length")?;
        self.stream.write_all(msg).context("writing frame payload")?;
        self.stream.flush().context("flushing frame")?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        let mut len = [0u8; 4];
        self.stream.read_exact(&mut len).context("reading frame length")?;
        let len = u32::from_le_bytes(len);
        anyhow::ensure!(len <= MAX_FRAME, "oversized frame: {len} bytes");
        let mut buf = vec![0u8; len as usize];
        self.stream.read_exact(&mut buf).context("reading frame payload")?;
        Ok(buf)
    }
}

/// Unix-domain-socket hub: binds a path and accepts `n` node
/// connections; node index = accept order (the init handshake tells each
/// process which index it got).
pub struct UdsTransport {
    chans: Vec<StreamChannel<UnixStream>>,
    path: PathBuf,
}

impl UdsTransport {
    /// Coordinator side: bind `path` (replacing any stale socket file)
    /// and accept exactly `n` node connections.
    pub fn listen(path: &Path, n: usize) -> Result<UdsTransport> {
        assert!(n >= 1, "a transport needs at least one node");
        let _ = std::fs::remove_file(path);
        let listener = UnixListener::bind(path)
            .with_context(|| format!("binding unix socket {}", path.display()))?;
        let mut chans = Vec::with_capacity(n);
        for _ in 0..n {
            let (stream, _) = listener.accept().context("accepting node connection")?;
            chans.push(StreamChannel::new(stream));
        }
        Ok(UdsTransport { chans, path: path.to_path_buf() })
    }

    /// Node side: connect to the coordinator's socket, retrying while
    /// the coordinator is still coming up (it may bind after the node
    /// process launches).
    pub fn connect(path: &Path, timeout: Duration) -> Result<StreamChannel<UnixStream>> {
        let deadline = Instant::now() + timeout;
        loop {
            match UnixStream::connect(path) {
                Ok(stream) => return Ok(StreamChannel::new(stream)),
                Err(e) => {
                    let retryable = matches!(
                        e.kind(),
                        std::io::ErrorKind::NotFound | std::io::ErrorKind::ConnectionRefused
                    );
                    if !retryable || Instant::now() >= deadline {
                        return Err(anyhow::Error::new(e)
                            .context(format!("connecting to {}", path.display())));
                    }
                    std::thread::sleep(Duration::from_millis(25));
                }
            }
        }
    }
}

impl Drop for UdsTransport {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

impl Transport for UdsTransport {
    fn name(&self) -> &'static str {
        "uds"
    }

    fn nodes(&self) -> usize {
        self.chans.len()
    }

    fn send_to(&mut self, node: usize, msg: &[u8]) -> Result<()> {
        self.chans[node].send(msg)
    }

    fn recv_from(&mut self, node: usize) -> Result<Vec<u8>> {
        self.chans[node].recv()
    }
}

/// TCP hub (loopback or LAN): same framing as [`UdsTransport`] over
/// `TcpStream`.
pub struct TcpTransport {
    chans: Vec<StreamChannel<TcpStream>>,
}

impl TcpTransport {
    /// Coordinator side: bind `addr` (e.g. `127.0.0.1:7171`) and accept
    /// exactly `n` node connections.
    pub fn listen(addr: &str, n: usize) -> Result<TcpTransport> {
        assert!(n >= 1, "a transport needs at least one node");
        let listener = TcpListener::bind(addr).with_context(|| format!("binding tcp {addr}"))?;
        let mut chans = Vec::with_capacity(n);
        for _ in 0..n {
            let (stream, _) = listener.accept().context("accepting node connection")?;
            stream.set_nodelay(true).ok(); // round-trips are latency-bound
            chans.push(StreamChannel::new(stream));
        }
        Ok(TcpTransport { chans })
    }

    /// Node side: connect with the same startup-race retry as UDS.
    pub fn connect(addr: &str, timeout: Duration) -> Result<StreamChannel<TcpStream>> {
        let deadline = Instant::now() + timeout;
        loop {
            match TcpStream::connect(addr) {
                Ok(stream) => {
                    stream.set_nodelay(true).ok();
                    return Ok(StreamChannel::new(stream));
                }
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(anyhow::Error::new(e).context(format!("connecting to {addr}")));
                    }
                    std::thread::sleep(Duration::from_millis(25));
                }
            }
        }
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn nodes(&self) -> usize {
        self.chans.len()
    }

    fn send_to(&mut self, node: usize, msg: &[u8]) -> Result<()> {
        self.chans[node].send(msg)
    }

    fn recv_from(&mut self, node: usize) -> Result<Vec<u8>> {
        self.chans[node].recv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inproc_request_reply_and_broadcast() {
        let (mut hub, ends) = InProcTransport::pair(3);
        assert_eq!(hub.nodes(), 3);
        let handles: Vec<_> = ends
            .into_iter()
            .enumerate()
            .map(|(i, mut chan)| {
                std::thread::spawn(move || {
                    let hello = chan.recv().unwrap();
                    assert_eq!(hello, b"ping");
                    chan.send(format!("pong {i}").as_bytes()).unwrap();
                })
            })
            .collect();
        hub.broadcast(b"ping").unwrap();
        for i in 0..3 {
            assert_eq!(hub.recv_from(i).unwrap(), format!("pong {i}").as_bytes());
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn inproc_disconnect_is_an_error() {
        let (mut hub, ends) = InProcTransport::pair(1);
        drop(ends);
        assert!(hub.recv_from(0).is_err());
        assert!(hub.send_to(0, b"x").is_err());
    }

    #[test]
    fn uds_frames_survive_the_socket() {
        let path = std::env::temp_dir()
            .join(format!("para-active-test-{}.sock", std::process::id()));
        let path2 = path.clone();
        let node = std::thread::spawn(move || {
            let mut chan = UdsTransport::connect(&path2, Duration::from_secs(5)).unwrap();
            let msg = chan.recv().unwrap();
            chan.send(&msg).unwrap(); // echo
            let empty = chan.recv().unwrap();
            assert!(empty.is_empty(), "zero-length frames are legal");
            chan.send(b"done").unwrap();
        });
        let mut hub = UdsTransport::listen(&path, 1).unwrap();
        let payload: Vec<u8> = (0..10_000u32).flat_map(|v| v.to_le_bytes()).collect();
        hub.send_to(0, &payload).unwrap();
        assert_eq!(hub.recv_from(0).unwrap(), payload);
        hub.send_to(0, b"").unwrap();
        assert_eq!(hub.recv_from(0).unwrap(), b"done");
        node.join().unwrap();
    }

    #[test]
    fn tcp_loopback_round_trip() {
        // Port 0 lets the OS pick; grab the real addr from the listener.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let node = std::thread::spawn(move || {
            let mut chan = TcpTransport::connect(&addr, Duration::from_secs(5)).unwrap();
            let msg = chan.recv().unwrap();
            chan.send(&msg).unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        let mut hub = TcpTransport { chans: vec![StreamChannel::new(stream)] };
        hub.send_to(0, b"over tcp").unwrap();
        assert_eq!(hub.recv_from(0).unwrap(), b"over tcp");
        node.join().unwrap();
    }
}
