//! Message transports: framing, channels, and the coordinator-side hub.
//!
//! Everything above this module speaks in whole byte messages. A
//! [`Channel`] is one side of a reliable, ordered message pipe; a
//! [`Transport`] is the coordinator's hub over one channel per remote
//! node process, with node-indexed request/reply and broadcast. Three
//! carriers implement the same framing:
//!
//! * [`InProcTransport`] — mpsc byte channels, the in-process sequencer
//!   path (`coordinator::broadcast`'s ordered-delivery role, carried by
//!   `std::sync::mpsc`'s FIFO guarantee). This is the carrier the
//!   bit-identity tests drive, and it makes the single-process
//!   coordinator just one [`Transport`] impl among equals;
//! * [`UdsTransport`] — Unix-domain stream sockets, the real two-process
//!   carrier on one machine;
//! * [`TcpTransport`] — loopback/LAN TCP, same framing over
//!   `TcpStream`.
//!
//! Stream carriers frame each message as a little-endian u32 length
//! prefix followed by the payload. The prefix is counted in the
//! [`NetStats`](super::NetStats) byte totals for every carrier —
//! including in-proc, where no bytes actually move — so wire telemetry
//! is comparable across carriers.
//!
//! Every receive has a deadline-aware variant ([`Channel::recv_deadline`]
//! / [`Transport::recv_from_deadline`]) reporting failures as the typed
//! [`NetError`](super::fault::NetError) taxonomy: deadline expiry is
//! `Timeout`, peer loss is `Disconnected`, and an impossible length
//! prefix is `Garbage`. A timed-out stream receive keeps the partial
//! frame buffered and resumes exactly where it left off on the next
//! call — a deadline never corrupts the framing.

use super::fault::{NetError, RetryPolicy};
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

/// Refuse frames above 1 GiB — anything bigger is a corrupted length
/// prefix, not a real message.
const MAX_FRAME: u32 = 1 << 30;

/// Per-frame overhead charged to the byte counters (the length prefix).
pub const FRAME_OVERHEAD: u64 = 4;

/// One side of a reliable, ordered byte-message pipe.
pub trait Channel: Send {
    /// Send one whole message.
    fn send(&mut self, msg: &[u8]) -> Result<()>;
    /// Block until the next whole message arrives.
    fn recv(&mut self) -> Result<Vec<u8>>;
    /// Wait at most `timeout` for the next whole message. On expiry the
    /// error classifies as [`NetError::Timeout`] and any partial frame
    /// stays buffered — the next receive resumes it byte-exactly.
    fn recv_deadline(&mut self, timeout: Duration) -> Result<Vec<u8>>;
}

/// The coordinator's hub: one [`Channel`] per connected node process,
/// indexed 0..nodes in accept/creation order.
pub trait Transport: Send {
    /// Carrier name for reports ("inproc", "uds", "tcp").
    fn name(&self) -> &'static str;
    /// Number of connected node processes.
    fn nodes(&self) -> usize;
    /// Send one message to node `node`.
    fn send_to(&mut self, node: usize, msg: &[u8]) -> Result<()>;
    /// Block until node `node`'s next message arrives.
    fn recv_from(&mut self, node: usize) -> Result<Vec<u8>>;
    /// Deadline-aware receive from node `node`; see
    /// [`Channel::recv_deadline`].
    fn recv_from_deadline(&mut self, node: usize, timeout: Duration) -> Result<Vec<u8>>;
    /// Send the same message to every node, in node order.
    fn broadcast(&mut self, msg: &[u8]) -> Result<()> {
        for node in 0..self.nodes() {
            self.send_to(node, msg)?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// In-process carrier.
// ---------------------------------------------------------------------

/// One endpoint of an in-process byte pipe (a pair of mpsc queues).
pub struct InProcChannel {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

impl Channel for InProcChannel {
    fn send(&mut self, msg: &[u8]) -> Result<()> {
        self.tx
            .send(msg.to_vec())
            .map_err(|_| anyhow::Error::new(NetError::Disconnected).context("in-proc peer gone"))
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        self.rx.recv().map_err(|_| {
            anyhow::Error::new(NetError::Disconnected).context("in-proc peer disconnected")
        })
    }

    fn recv_deadline(&mut self, timeout: Duration) -> Result<Vec<u8>> {
        match self.rx.recv_timeout(timeout) {
            Ok(msg) => Ok(msg),
            Err(RecvTimeoutError::Timeout) => Err(anyhow::Error::new(NetError::Timeout)),
            Err(RecvTimeoutError::Disconnected) => Err(anyhow::Error::new(NetError::Disconnected)
                .context("in-proc peer disconnected")),
        }
    }
}

/// The in-process hub: node endpoints live on other threads of the same
/// process, connected by FIFO mpsc queues.
pub struct InProcTransport {
    chans: Vec<InProcChannel>,
}

impl InProcTransport {
    /// Create a hub plus `n` node endpoints. Endpoint `i` talks to hub
    /// slot `i`; hand each endpoint to one node thread.
    pub fn pair(n: usize) -> (InProcTransport, Vec<InProcChannel>) {
        assert!(n >= 1, "a transport needs at least one node");
        let mut hub = Vec::with_capacity(n);
        let mut ends = Vec::with_capacity(n);
        for _ in 0..n {
            let (to_node, node_rx) = channel();
            let (to_hub, hub_rx) = channel();
            hub.push(InProcChannel { tx: to_node, rx: hub_rx });
            ends.push(InProcChannel { tx: to_hub, rx: node_rx });
        }
        (InProcTransport { chans: hub }, ends)
    }
}

impl Transport for InProcTransport {
    fn name(&self) -> &'static str {
        "inproc"
    }

    fn nodes(&self) -> usize {
        self.chans.len()
    }

    fn send_to(&mut self, node: usize, msg: &[u8]) -> Result<()> {
        self.chans[node].send(msg)
    }

    fn recv_from(&mut self, node: usize) -> Result<Vec<u8>> {
        self.chans[node].recv()
    }

    fn recv_from_deadline(&mut self, node: usize, timeout: Duration) -> Result<Vec<u8>> {
        self.chans[node].recv_deadline(timeout)
    }
}

// ---------------------------------------------------------------------
// Stream carriers (UDS, TCP): length-prefix framing over Read + Write.
// ---------------------------------------------------------------------

/// A byte stream whose reads can be given an OS-level deadline. Both
/// socket types expose this as `set_read_timeout`; the trait lets
/// [`StreamChannel`] stay generic over them.
pub trait DeadlineRead {
    /// Set (or clear, with `None`) the read timeout on the underlying
    /// descriptor. `Some(Duration::ZERO)` is an OS error — callers must
    /// clamp first.
    fn set_read_deadline(&self, timeout: Option<Duration>) -> std::io::Result<()>;
}

impl DeadlineRead for UnixStream {
    fn set_read_deadline(&self, timeout: Option<Duration>) -> std::io::Result<()> {
        self.set_read_timeout(timeout)
    }
}

impl DeadlineRead for TcpStream {
    fn set_read_deadline(&self, timeout: Option<Duration>) -> std::io::Result<()> {
        self.set_read_timeout(timeout)
    }
}

/// Length-prefix framing over any byte stream. Receives are resumable:
/// bytes of an in-flight frame accumulate in `partial` across
/// deadline-expired calls, so a slow peer is indistinguishable from a
/// fast one once its frame finally lands.
pub struct StreamChannel<S: Read + Write + Send + DeadlineRead> {
    stream: S,
    /// Header + payload bytes of the frame currently being read.
    partial: Vec<u8>,
}

impl<S: Read + Write + Send + DeadlineRead> StreamChannel<S> {
    pub fn new(stream: S) -> Self {
        StreamChannel { stream, partial: Vec::new() }
    }

    /// Read until the in-flight frame completes or `deadline` passes
    /// (`None` = block forever). Partial progress survives timeouts.
    fn recv_until(&mut self, deadline: Option<Instant>) -> Result<Vec<u8>> {
        loop {
            // Total bytes the in-flight frame needs (header first, then
            // header + payload once the length prefix is complete).
            let target = if self.partial.len() < 4 {
                4
            } else {
                let len = u32::from_le_bytes(self.partial[..4].try_into().expect("4-byte slice"));
                if len > MAX_FRAME {
                    return Err(anyhow::Error::new(NetError::Garbage(format!(
                        "oversized frame: {len} bytes"
                    ))));
                }
                4 + len as usize
            };
            if self.partial.len() >= 4 && self.partial.len() == target {
                let payload = self.partial.split_off(4);
                self.partial.clear();
                return Ok(payload);
            }
            match deadline {
                Some(d) => {
                    let remaining = d.saturating_duration_since(Instant::now());
                    if remaining.is_zero() {
                        return Err(anyhow::Error::new(NetError::Timeout));
                    }
                    self.stream
                        .set_read_deadline(Some(remaining))
                        .context("setting read deadline")?;
                }
                None => {
                    self.stream.set_read_deadline(None).context("clearing read deadline")?;
                }
            }
            let mut buf = vec![0u8; target - self.partial.len()];
            match self.stream.read(&mut buf) {
                Ok(0) => {
                    return Err(anyhow::Error::new(NetError::Disconnected)
                        .context("peer closed the stream"));
                }
                Ok(n) => self.partial.extend_from_slice(&buf[..n]),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    return Err(anyhow::Error::new(NetError::Timeout));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    return Err(anyhow::Error::new(NetError::Disconnected)
                        .context(format!("stream read failed: {e}")));
                }
            }
        }
    }
}

impl<S: Read + Write + Send + DeadlineRead> Channel for StreamChannel<S> {
    fn send(&mut self, msg: &[u8]) -> Result<()> {
        let len = u32::try_from(msg.len())
            .ok()
            .filter(|&l| l <= MAX_FRAME)
            .with_context(|| format!("frame too large: {} bytes", msg.len()))?;
        self.stream.write_all(&len.to_le_bytes()).context("writing frame length")?;
        self.stream.write_all(msg).context("writing frame payload")?;
        self.stream.flush().context("flushing frame")?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        self.recv_until(None)
    }

    fn recv_deadline(&mut self, timeout: Duration) -> Result<Vec<u8>> {
        self.recv_until(Some(Instant::now() + timeout))
    }
}

/// Unix-domain-socket hub: binds a path and accepts `n` node
/// connections; node index = accept order (the init handshake tells each
/// process which index it got).
pub struct UdsTransport {
    chans: Vec<StreamChannel<UnixStream>>,
    path: PathBuf,
}

impl UdsTransport {
    /// Coordinator side: bind `path` (replacing any stale socket file)
    /// and accept exactly `n` node connections.
    pub fn listen(path: &Path, n: usize) -> Result<UdsTransport> {
        assert!(n >= 1, "a transport needs at least one node");
        let _ = std::fs::remove_file(path);
        let listener = UnixListener::bind(path)
            .with_context(|| format!("binding unix socket {}", path.display()))?;
        let mut chans = Vec::with_capacity(n);
        for _ in 0..n {
            let (stream, _) = listener.accept().context("accepting node connection")?;
            chans.push(StreamChannel::new(stream));
        }
        Ok(UdsTransport { chans, path: path.to_path_buf() })
    }

    /// Node side: connect to the coordinator's socket, retrying with
    /// seeded exponential backoff while the coordinator is still coming
    /// up (it may bind after the node process launches).
    pub fn connect(path: &Path, timeout: Duration) -> Result<StreamChannel<UnixStream>> {
        let deadline = Instant::now() + timeout;
        let mut policy = RetryPolicy::for_connect(addr_seed(&path.display().to_string()));
        let mut attempt = 0u32;
        loop {
            match UnixStream::connect(path) {
                Ok(stream) => return Ok(StreamChannel::new(stream)),
                Err(e) => {
                    let retryable = matches!(
                        e.kind(),
                        std::io::ErrorKind::NotFound | std::io::ErrorKind::ConnectionRefused
                    );
                    if !retryable || Instant::now() >= deadline {
                        return Err(anyhow::Error::new(e)
                            .context(format!("connecting to {}", path.display())));
                    }
                    std::thread::sleep(policy.delay(attempt));
                    attempt += 1;
                }
            }
        }
    }
}

/// Deterministic backoff seed from the connect target, so two nodes
/// dialing different sockets don't share a jitter sequence.
fn addr_seed(addr: &str) -> u64 {
    addr.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| (h ^ u64::from(b)).wrapping_mul(0x100_0000_01b3))
}

impl Drop for UdsTransport {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

impl Transport for UdsTransport {
    fn name(&self) -> &'static str {
        "uds"
    }

    fn nodes(&self) -> usize {
        self.chans.len()
    }

    fn send_to(&mut self, node: usize, msg: &[u8]) -> Result<()> {
        self.chans[node].send(msg)
    }

    fn recv_from(&mut self, node: usize) -> Result<Vec<u8>> {
        self.chans[node].recv()
    }

    fn recv_from_deadline(&mut self, node: usize, timeout: Duration) -> Result<Vec<u8>> {
        self.chans[node].recv_deadline(timeout)
    }
}

/// TCP hub (loopback or LAN): same framing as [`UdsTransport`] over
/// `TcpStream`.
pub struct TcpTransport {
    chans: Vec<StreamChannel<TcpStream>>,
}

impl TcpTransport {
    /// Coordinator side: bind `addr` (e.g. `127.0.0.1:7171`) and accept
    /// exactly `n` node connections.
    pub fn listen(addr: &str, n: usize) -> Result<TcpTransport> {
        assert!(n >= 1, "a transport needs at least one node");
        let listener = TcpListener::bind(addr).with_context(|| format!("binding tcp {addr}"))?;
        let mut chans = Vec::with_capacity(n);
        for _ in 0..n {
            let (stream, _) = listener.accept().context("accepting node connection")?;
            stream.set_nodelay(true).ok(); // round-trips are latency-bound
            chans.push(StreamChannel::new(stream));
        }
        Ok(TcpTransport { chans })
    }

    /// Node side: connect with the same startup-race backoff as UDS.
    pub fn connect(addr: &str, timeout: Duration) -> Result<StreamChannel<TcpStream>> {
        let deadline = Instant::now() + timeout;
        let mut policy = RetryPolicy::for_connect(addr_seed(addr));
        let mut attempt = 0u32;
        loop {
            match TcpStream::connect(addr) {
                Ok(stream) => {
                    stream.set_nodelay(true).ok();
                    return Ok(StreamChannel::new(stream));
                }
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(anyhow::Error::new(e).context(format!("connecting to {addr}")));
                    }
                    std::thread::sleep(policy.delay(attempt));
                    attempt += 1;
                }
            }
        }
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn nodes(&self) -> usize {
        self.chans.len()
    }

    fn send_to(&mut self, node: usize, msg: &[u8]) -> Result<()> {
        self.chans[node].send(msg)
    }

    fn recv_from(&mut self, node: usize) -> Result<Vec<u8>> {
        self.chans[node].recv()
    }

    fn recv_from_deadline(&mut self, node: usize, timeout: Duration) -> Result<Vec<u8>> {
        self.chans[node].recv_deadline(timeout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inproc_request_reply_and_broadcast() {
        let (mut hub, ends) = InProcTransport::pair(3);
        assert_eq!(hub.nodes(), 3);
        let handles: Vec<_> = ends
            .into_iter()
            .enumerate()
            .map(|(i, mut chan)| {
                std::thread::spawn(move || {
                    let hello = chan.recv().unwrap();
                    assert_eq!(hello, b"ping");
                    chan.send(format!("pong {i}").as_bytes()).unwrap();
                })
            })
            .collect();
        hub.broadcast(b"ping").unwrap();
        for i in 0..3 {
            assert_eq!(hub.recv_from(i).unwrap(), format!("pong {i}").as_bytes());
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn inproc_disconnect_is_an_error() {
        let (mut hub, ends) = InProcTransport::pair(1);
        drop(ends);
        assert!(hub.recv_from(0).is_err());
        assert!(hub.send_to(0, b"x").is_err());
    }

    #[test]
    fn uds_frames_survive_the_socket() {
        let path = std::env::temp_dir()
            .join(format!("para-active-test-{}.sock", std::process::id()));
        let path2 = path.clone();
        let node = std::thread::spawn(move || {
            let mut chan = UdsTransport::connect(&path2, Duration::from_secs(5)).unwrap();
            let msg = chan.recv().unwrap();
            chan.send(&msg).unwrap(); // echo
            let empty = chan.recv().unwrap();
            assert!(empty.is_empty(), "zero-length frames are legal");
            chan.send(b"done").unwrap();
        });
        let mut hub = UdsTransport::listen(&path, 1).unwrap();
        let payload: Vec<u8> = (0..10_000u32).flat_map(|v| v.to_le_bytes()).collect();
        hub.send_to(0, &payload).unwrap();
        assert_eq!(hub.recv_from(0).unwrap(), payload);
        hub.send_to(0, b"").unwrap();
        assert_eq!(hub.recv_from(0).unwrap(), b"done");
        node.join().unwrap();
    }

    #[test]
    fn inproc_deadline_times_out_then_delivers_then_disconnects() {
        let (mut hub, mut ends) = InProcTransport::pair(1);
        let err = hub.recv_from_deadline(0, Duration::from_millis(10)).unwrap_err();
        assert_eq!(NetError::classify(&err), Some(&NetError::Timeout));
        ends[0].send(b"late").unwrap();
        assert_eq!(hub.recv_from_deadline(0, Duration::from_secs(5)).unwrap(), b"late");
        drop(ends);
        let err = hub.recv_from_deadline(0, Duration::from_secs(1)).unwrap_err();
        assert_eq!(NetError::classify(&err), Some(&NetError::Disconnected));
    }

    #[test]
    fn stream_deadline_preserves_a_partial_frame() {
        let (a, mut peer) = UnixStream::pair().unwrap();
        let mut chan = StreamChannel::new(a);
        // Only the header plus half the payload arrives before the
        // deadline: the receive must time out WITHOUT corrupting the
        // framing, then resume to the complete message.
        peer.write_all(&8u32.to_le_bytes()).unwrap();
        peer.write_all(b"half").unwrap();
        let err = chan.recv_deadline(Duration::from_millis(30)).unwrap_err();
        assert_eq!(NetError::classify(&err), Some(&NetError::Timeout));
        peer.write_all(b"more").unwrap();
        assert_eq!(chan.recv_deadline(Duration::from_secs(5)).unwrap(), b"halfmore");
        // The stream is clean for the next frame.
        peer.write_all(&2u32.to_le_bytes()).unwrap();
        peer.write_all(b"ok").unwrap();
        assert_eq!(chan.recv().unwrap(), b"ok");
    }

    #[test]
    fn stream_errors_classify_as_disconnect_and_garbage() {
        let (a, peer) = UnixStream::pair().unwrap();
        let mut chan = StreamChannel::new(a);
        drop(peer);
        let err = chan.recv_deadline(Duration::from_secs(1)).unwrap_err();
        assert_eq!(NetError::classify(&err), Some(&NetError::Disconnected));

        let (a, mut peer) = UnixStream::pair().unwrap();
        let mut chan = StreamChannel::new(a);
        peer.write_all(&u32::MAX.to_le_bytes()).unwrap();
        let err = chan.recv_deadline(Duration::from_secs(1)).unwrap_err();
        assert!(matches!(NetError::classify(&err), Some(NetError::Garbage(_))));
    }

    #[test]
    fn tcp_loopback_round_trip() {
        // Port 0 lets the OS pick; grab the real addr from the listener.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let node = std::thread::spawn(move || {
            let mut chan = TcpTransport::connect(&addr, Duration::from_secs(5)).unwrap();
            let msg = chan.recv().unwrap();
            chan.send(&msg).unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        let mut hub = TcpTransport { chans: vec![StreamChannel::new(stream)] };
        hub.send_to(0, b"over tcp").unwrap();
        assert_eq!(hub.recv_from(0).unwrap(), b"over tcp");
        node.join().unwrap();
    }
}
