//! The coordinator ↔ sift-node message set.
//!
//! One round trip per round: the coordinator broadcasts [`Msg::Round`]
//! (phase counter + model sync) and collects one [`Msg::Sift`] per node
//! process (per-lane selections, in lane order). Example data never
//! crosses the wire — [`Msg::Init`] carries just enough for a node to
//! regenerate its lanes deterministically (stream seed, sifter spec,
//! lane range), which is what keeps the wire cost `O(model delta +
//! selections)` instead of `O(shard)`.
//!
//! Encoding is the little-endian packing of [`super::wire`]; every
//! message starts with a one-byte tag. [`Msg::decode`] turns truncation
//! or unknown tags into errors, never panics — a transport delivers
//! whatever the peer sent.

use super::delta::SyncMessage;
use super::wire::{put_f32s, put_f64, put_len, put_u32, put_u64, put_u8, Reader};
use crate::active::SifterSpec;
use crate::coordinator::backend::NodeSift;
use crate::exec::PoolStats;
use anyhow::Result;

/// Bump on any wire-format change; [`Msg::Init`] carries it and
/// [`super::node::serve_sift_node`] refuses mismatches. v2 added the
/// Ping/Pong heartbeat pair — a v1 node cleanly rejects a v2
/// coordinator at the handshake instead of choking mid-run.
pub const PROTO_VERSION: u32 = 2;

const TAG_INIT: u8 = 1;
const TAG_READY: u8 = 2;
const TAG_ROUND: u8 = 3;
const TAG_SIFT: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;
const TAG_BYE: u8 = 6;
const TAG_PING: u8 = 7;
const TAG_PONG: u8 = 8;

/// If `frame` is an encoded [`Msg::Round`], its round number. Lets a
/// transport wrapper (the fault injector) track round progress by
/// watching outgoing frames, without decoding full messages.
pub(crate) fn peek_round(frame: &[u8]) -> Option<u64> {
    if frame.len() >= 9 && frame[0] == TAG_ROUND {
        Some(u64::from_le_bytes(frame[1..9].try_into().expect("8-byte slice")))
    } else {
        None
    }
}

/// Which experiment family a run belongs to. Carried in [`Msg::Init`] so
/// a node launched with the wrong subcommand fails fast instead of
/// silently scoring with the wrong learner.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    Svm,
    Nn,
}

impl TaskKind {
    fn as_u8(self) -> u8 {
        match self {
            TaskKind::Svm => 0,
            TaskKind::Nn => 1,
        }
    }

    fn from_u8(v: u8) -> Result<Self> {
        match v {
            0 => Ok(TaskKind::Svm),
            1 => Ok(TaskKind::Nn),
            other => anyhow::bail!("unknown task kind {other}"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TaskKind::Svm => "svm",
            TaskKind::Nn => "nn",
        }
    }
}

/// Round-zero handshake: everything a node needs to rebuild its slice of
/// the coordinator's lane array bit-identically.
#[derive(Debug, Clone, PartialEq)]
pub struct InitMsg {
    pub version: u32,
    pub task: TaskKind,
    /// Caller-computed digest of the out-of-band run configuration
    /// (learner hyper-parameters, stream task). Both sides must agree;
    /// see [`super::cluster::config_fingerprint`].
    pub fingerprint: u64,
    /// Index of this node process on the transport.
    pub node_index: u32,
    /// Lane range [lane_lo, lane_hi) this process sifts.
    pub lane_lo: u32,
    pub lane_hi: u32,
    /// Total lane count k of the run (for context in errors).
    pub k: u32,
    /// Per-lane shard size B/k.
    pub shard: u32,
    /// Examples to skip on lane 0 before the first round (the warmstart
    /// head the coordinator consumed locally). Zero for lanes > 0.
    pub skip: u64,
    /// Seed of the example stream config (lanes salt it by lane id).
    pub stream_seed: u64,
    pub sifter: SifterSpec,
}

/// Node acknowledgment of [`InitMsg`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReadyMsg {
    pub node_index: u32,
    pub lanes: u32,
}

/// One round's work order: the phase counter and the model sync.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundMsg {
    pub round: u64,
    /// Cumulative examples seen by the cluster before this phase (the
    /// paper's n in Eq 5).
    pub n_phase: u64,
    pub sync: SyncMessage,
}

/// One node process's sift results: one [`NodeSift`] per owned lane, in
/// lane order.
#[derive(Debug, Clone)]
pub struct SiftMsg {
    pub round: u64,
    pub lanes: Vec<NodeSift>,
}

/// Node's parting stats, sent in reply to [`Msg::Shutdown`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ByeMsg {
    pub pool: PoolStats,
}

/// Every message that crosses a [`super::transport::Channel`].
#[derive(Debug, Clone)]
pub enum Msg {
    Init(InitMsg),
    Ready(ReadyMsg),
    Round(RoundMsg),
    Sift(SiftMsg),
    Shutdown,
    Bye(ByeMsg),
    /// Coordinator liveness probe (sequence number echoed by the Pong).
    /// Sent while waiting out a slow node and when probing a dead one.
    Ping(u64),
    /// Node's echo of a [`Msg::Ping`]: "still here, still sifting".
    Pong(u64),
}

fn put_sifter(buf: &mut Vec<u8>, s: &SifterSpec) {
    match *s {
        SifterSpec::Passive => put_u8(buf, 0),
        SifterSpec::Margin { eta, seed } => {
            put_u8(buf, 1);
            put_f64(buf, eta);
            put_u64(buf, seed);
        }
        SifterSpec::FixedRate { rate, seed } => {
            put_u8(buf, 2);
            put_f64(buf, rate);
            put_u64(buf, seed);
        }
    }
}

fn read_sifter(r: &mut Reader<'_>) -> Result<SifterSpec> {
    match r.u8()? {
        0 => Ok(SifterSpec::Passive),
        1 => Ok(SifterSpec::Margin { eta: r.f64()?, seed: r.u64()? }),
        2 => Ok(SifterSpec::FixedRate { rate: r.f64()?, seed: r.u64()? }),
        other => anyhow::bail!("unknown sifter variant {other}"),
    }
}

impl Msg {
    /// Errors when a length prefix would overflow its u32 slot — the
    /// encode-side mirror of [`Msg::decode`]'s truncation errors.
    pub fn encode(&self) -> Result<Vec<u8>> {
        let mut buf = Vec::new();
        match self {
            Msg::Init(m) => {
                put_u8(&mut buf, TAG_INIT);
                put_u32(&mut buf, m.version);
                put_u8(&mut buf, m.task.as_u8());
                put_u64(&mut buf, m.fingerprint);
                put_u32(&mut buf, m.node_index);
                put_u32(&mut buf, m.lane_lo);
                put_u32(&mut buf, m.lane_hi);
                put_u32(&mut buf, m.k);
                put_u32(&mut buf, m.shard);
                put_u64(&mut buf, m.skip);
                put_u64(&mut buf, m.stream_seed);
                put_sifter(&mut buf, &m.sifter);
            }
            Msg::Ready(m) => {
                put_u8(&mut buf, TAG_READY);
                put_u32(&mut buf, m.node_index);
                put_u32(&mut buf, m.lanes);
            }
            Msg::Round(m) => {
                put_u8(&mut buf, TAG_ROUND);
                put_u64(&mut buf, m.round);
                put_u64(&mut buf, m.n_phase);
                put_u64(&mut buf, m.sync.epoch);
                put_u8(&mut buf, m.sync.full as u8);
                put_len(&mut buf, m.sync.payload.len())?;
                buf.extend_from_slice(&m.sync.payload);
            }
            Msg::Sift(m) => {
                put_u8(&mut buf, TAG_SIFT);
                put_u64(&mut buf, m.round);
                put_len(&mut buf, m.lanes.len())?;
                for lane in &m.lanes {
                    put_f32s(&mut buf, &lane.sel_x)?;
                    put_f32s(&mut buf, &lane.sel_y)?;
                    put_f32s(&mut buf, &lane.sel_w)?;
                    put_f64(&mut buf, lane.seconds);
                    put_u64(&mut buf, lane.sift_ops);
                }
            }
            Msg::Shutdown => put_u8(&mut buf, TAG_SHUTDOWN),
            Msg::Bye(m) => {
                put_u8(&mut buf, TAG_BYE);
                put_len(&mut buf, m.pool.workers)?;
                put_u64(&mut buf, m.pool.threads_spawned);
                put_u64(&mut buf, m.pool.rounds);
            }
            Msg::Ping(seq) => {
                put_u8(&mut buf, TAG_PING);
                put_u64(&mut buf, *seq);
            }
            Msg::Pong(seq) => {
                put_u8(&mut buf, TAG_PONG);
                put_u64(&mut buf, *seq);
            }
        }
        Ok(buf)
    }

    pub fn decode(bytes: &[u8]) -> Result<Msg> {
        let mut r = Reader::new(bytes);
        let msg = match r.u8()? {
            TAG_INIT => Msg::Init(InitMsg {
                version: r.u32()?,
                task: TaskKind::from_u8(r.u8()?)?,
                fingerprint: r.u64()?,
                node_index: r.u32()?,
                lane_lo: r.u32()?,
                lane_hi: r.u32()?,
                k: r.u32()?,
                shard: r.u32()?,
                skip: r.u64()?,
                stream_seed: r.u64()?,
                sifter: read_sifter(&mut r)?,
            }),
            TAG_READY => Msg::Ready(ReadyMsg { node_index: r.u32()?, lanes: r.u32()? }),
            TAG_ROUND => {
                let round = r.u64()?;
                let n_phase = r.u64()?;
                let epoch = r.u64()?;
                let full = r.u8()? != 0;
                let len = r.u32()? as usize;
                let payload = r.bytes(len)?;
                Msg::Round(RoundMsg { round, n_phase, sync: SyncMessage { epoch, full, payload } })
            }
            TAG_SIFT => {
                let round = r.u64()?;
                let n = r.u32()? as usize;
                // Every lane costs >= 28 wire bytes (three length
                // prefixes + seconds + sift_ops), so a count the
                // remaining bytes cannot cover is garbage — reject it
                // before reserving lane structs for it.
                anyhow::ensure!(
                    n <= r.remaining() / 28,
                    "sift message claims {n} lanes but only {} bytes remain",
                    r.remaining()
                );
                let mut lanes = Vec::with_capacity(n);
                for _ in 0..n {
                    let sel_x = r.f32s()?;
                    let sel_y = r.f32s()?;
                    let sel_w = r.f32s()?;
                    let seconds = r.f64()?;
                    let sift_ops = r.u64()?;
                    lanes.push(NodeSift { sel_x, sel_y, sel_w, seconds, sift_ops });
                }
                Msg::Sift(SiftMsg { round, lanes })
            }
            TAG_SHUTDOWN => Msg::Shutdown,
            TAG_BYE => Msg::Bye(ByeMsg {
                pool: PoolStats {
                    workers: r.u32()? as usize,
                    threads_spawned: r.u64()?,
                    rounds: r.u64()?,
                },
            }),
            TAG_PING => Msg::Ping(r.u64()?),
            TAG_PONG => Msg::Pong(r.u64()?),
            other => anyhow::bail!("unknown message tag {other}"),
        };
        anyhow::ensure!(r.remaining() == 0, "{} trailing bytes after message", r.remaining());
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_roundtrip_preserves_every_field() {
        let m = InitMsg {
            version: PROTO_VERSION,
            task: TaskKind::Nn,
            fingerprint: 0xFEED_F00D,
            node_index: 1,
            lane_lo: 2,
            lane_hi: 4,
            k: 4,
            shard: 500,
            skip: 4000,
            stream_seed: 0x5EED_5EED,
            sifter: SifterSpec::Margin { eta: 0.1, seed: 7 },
        };
        match Msg::decode(&Msg::Init(m.clone()).encode().unwrap()).unwrap() {
            Msg::Init(got) => assert_eq!(got, m),
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn sift_roundtrip_is_bit_exact() {
        let lane = NodeSift {
            sel_x: vec![1.5, -0.0, f32::MIN_POSITIVE],
            sel_y: vec![1.0],
            sel_w: vec![3.25],
            seconds: 0.75,
            sift_ops: 99,
        };
        let m = SiftMsg { round: 3, lanes: vec![lane.clone(), NodeSift::default()] };
        match Msg::decode(&Msg::Sift(m).encode().unwrap()).unwrap() {
            Msg::Sift(got) => {
                assert_eq!(got.round, 3);
                assert_eq!(got.lanes.len(), 2);
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&got.lanes[0].sel_x), bits(&lane.sel_x));
                assert_eq!(got.lanes[0].sift_ops, 99);
                assert!(got.lanes[1].sel_y.is_empty());
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn round_carries_sync_payload_and_rejects_trailing_bytes() {
        let m = Msg::Round(RoundMsg {
            round: 9,
            n_phase: 8000,
            sync: SyncMessage { epoch: 9, full: false, payload: vec![1, 2, 3] },
        });
        let mut bytes = m.encode().unwrap();
        match Msg::decode(&bytes).unwrap() {
            Msg::Round(got) => {
                assert!(!got.sync.full);
                assert_eq!(got.sync.payload, vec![1, 2, 3]);
            }
            other => panic!("wrong variant: {other:?}"),
        }
        bytes.push(0);
        assert!(Msg::decode(&bytes).is_err(), "trailing garbage must not parse");
        assert!(Msg::decode(&[250]).is_err(), "unknown tag must not parse");
    }

    #[test]
    fn ping_pong_roundtrip_and_round_peek() {
        match Msg::decode(&Msg::Ping(41).encode().unwrap()).unwrap() {
            Msg::Ping(seq) => assert_eq!(seq, 41),
            other => panic!("wrong variant: {other:?}"),
        }
        match Msg::decode(&Msg::Pong(42).encode().unwrap()).unwrap() {
            Msg::Pong(seq) => assert_eq!(seq, 42),
            other => panic!("wrong variant: {other:?}"),
        }
        let round = Msg::Round(RoundMsg {
            round: 77,
            n_phase: 0,
            sync: SyncMessage { epoch: 77, full: true, payload: vec![] },
        });
        assert_eq!(peek_round(&round.encode().unwrap()), Some(77));
        assert_eq!(peek_round(&Msg::Ping(77).encode().unwrap()), None);
        assert_eq!(peek_round(b"xy"), None);
    }

    #[test]
    fn shutdown_and_bye_roundtrip() {
        assert!(matches!(Msg::decode(&Msg::Shutdown.encode().unwrap()).unwrap(), Msg::Shutdown));
        let bye = ByeMsg { pool: PoolStats { workers: 3, threads_spawned: 3, rounds: 17 } };
        match Msg::decode(&Msg::Bye(bye).encode().unwrap()).unwrap() {
            Msg::Bye(got) => assert_eq!(got, bye),
            other => panic!("wrong variant: {other:?}"),
        }
    }
}
