//! Minimal benchmarking harness.
//!
//! The offline build environment pins the vendor set (no criterion), so the
//! `cargo bench` targets use this self-contained timer: warmup, repeated
//! timed runs, and a one-line mean/min/max report per benchmark, plus an
//! optional throughput figure. Output is stable, grep-friendly, and used by
//! EXPERIMENTS.md §Perf.

use std::time::Instant;

/// Timing summary of one benchmark.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchStats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }
}

/// Run `f` `iters` times (after `warmup` unmeasured runs) and report.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    let mean_s = times.iter().sum::<f64>() / iters as f64;
    let min_s = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max_s = times.iter().cloned().fold(0.0f64, f64::max);
    let stats = BenchStats { iters, mean_s, min_s, max_s };
    println!(
        "bench {name:48} {:>10.3} ms/iter  (min {:.3}, max {:.3}, n={iters})",
        stats.mean_ms(),
        min_s * 1e3,
        max_s * 1e3
    );
    stats
}

/// Like [`bench`] but also prints a throughput line (`units` per call).
pub fn bench_throughput<F: FnMut()>(
    name: &str,
    units_per_iter: f64,
    unit: &str,
    warmup: usize,
    iters: usize,
    f: F,
) -> BenchStats {
    let stats = bench(name, warmup, iters, f);
    println!(
        "      {name:48} {:>10.0} {unit}/s",
        units_per_iter / stats.mean_s
    );
    stats
}

/// Guard against the optimizer deleting benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let mut count = 0u64;
        let s = bench("noop-spin", 1, 5, || {
            for i in 0..1000u64 {
                count = black_box(count.wrapping_add(i));
            }
        });
        assert_eq!(s.iters, 5);
        assert!(s.min_s <= s.mean_s && s.mean_s <= s.max_s);
        assert!(s.mean_s >= 0.0);
    }

    #[test]
    fn throughput_is_positive() {
        let s = bench_throughput("tiny", 100.0, "ops", 0, 3, || {
            black_box(42u64);
        });
        assert!(s.mean_s >= 0.0);
    }
}
