//! Minimal benchmarking harness.
//!
//! The offline build environment pins the vendor set (no criterion), so the
//! `cargo bench` targets use this self-contained timer: warmup, repeated
//! timed runs, and a one-line mean/min/max report per benchmark, plus an
//! optional throughput figure. Output is stable, grep-friendly, and used by
//! EXPERIMENTS.md §Perf.

use crate::obs::Histogram;
use std::time::Instant;

/// Timing summary of one benchmark. Mean/min/max are exact (the
/// [`Histogram`] tracks them alongside its buckets); the median is
/// bucket-quantized, within a factor of 2^(1/4) of exact.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub p50_s: f64,
}

impl BenchStats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }
}

/// Run `f` `iters` times (after `warmup` unmeasured runs) and report.
/// Per-iteration times land in an `obs::hist` [`Histogram`] — the same
/// summary-stat machinery the serve session and coordinator use.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut hist = Histogram::new();
    for _ in 0..iters {
        let t = Instant::now();
        f();
        hist.record(t.elapsed().as_secs_f64());
    }
    let stats = BenchStats {
        iters,
        mean_s: hist.mean(),
        min_s: hist.min(),
        max_s: hist.max(),
        p50_s: hist.quantile(0.5),
    };
    println!(
        "bench {name:48} {:>10.3} ms/iter  (p50 {:.3}, min {:.3}, max {:.3}, n={iters})",
        stats.mean_ms(),
        stats.p50_s * 1e3,
        stats.min_s * 1e3,
        stats.max_s * 1e3
    );
    stats
}

/// Like [`bench`] but also prints a throughput line (`units` per call).
pub fn bench_throughput<F: FnMut()>(
    name: &str,
    units_per_iter: f64,
    unit: &str,
    warmup: usize,
    iters: usize,
    f: F,
) -> BenchStats {
    let stats = bench(name, warmup, iters, f);
    println!(
        "      {name:48} {:>10.0} {unit}/s",
        units_per_iter / stats.mean_s
    );
    stats
}

/// Guard against the optimizer deleting benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let mut count = 0u64;
        let s = bench("noop-spin", 1, 5, || {
            for i in 0..1000u64 {
                count = black_box(count.wrapping_add(i));
            }
        });
        assert_eq!(s.iters, 5);
        assert!(s.min_s <= s.mean_s && s.mean_s <= s.max_s);
        assert!(s.mean_s >= 0.0);
        assert!(s.p50_s >= s.min_s && s.p50_s <= s.max_s);
    }

    #[test]
    fn throughput_is_positive() {
        let s = bench_throughput("tiny", 100.0, "ops", 0, 3, || {
            black_box(42u64);
        });
        assert!(s.mean_s >= 0.0);
    }
}
