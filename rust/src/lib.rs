//! # para-active — parallel learning via active-learning sifting
//!
//! A production reproduction of *"Para-active learning"* (Agarwal, Bottou,
//! Dudík, Langford; cs.LG 2013): active-learning machinery is used not to
//! save labels but to **parallelize** learners that are otherwise hard to
//! parallelize (kernel SVMs, SGD-trained neural networks). Each node runs a
//! *sifter* (scores incoming examples with a slightly stale model and
//! selects informative ones via the margin rule, Eq 5) and an *updater*
//! (replays the globally-ordered broadcast of selected examples into its
//! model replica).
//!
//! Layering (see DESIGN.md):
//! * **L3 (this crate)** — coordinator: synchronous rounds ([`coordinator::sync`],
//!   Algorithm 1) over pluggable sift backends ([`coordinator::backend`],
//!   serial or real threads — bit-identical by contract) backed by the
//!   persistent execution pool ([`exec`]: cross-round worker pool,
//!   per-worker scorer instances, minibatched bounded-staleness update
//!   replay), asynchronous dual-queue protocol ([`coordinator::async_sim`],
//!   Algorithm 2), IWAL with delays ([`active::iwal`], Algorithm 3), the
//!   LASVM solver ([`svm`]), the MLP trainer ([`nn`]), the data substrate
//!   ([`data`]), cluster timing simulation ([`sim`]), metrics ([`metrics`]).
//! * **L2/L1 (python/, build-time only)** — JAX sift graphs built on Pallas
//!   kernels, AOT-lowered to `artifacts/*.hlo.txt`, loaded and executed from
//!   rust via PJRT in [`runtime`]. Python is never on the request path.
//!
//! Quickstart:
//! ```no_run
//! use para_active::prelude::*;
//!
//! let cfg = SvmExperimentConfig::paper_defaults();
//! let stream_cfg = StreamConfig::svm_task(); // {3,1} vs {5,7}
//! let report = run_sync_svm(&cfg, &stream_cfg, /*nodes=*/4, /*budget=*/50_000);
//! println!("final test error: {}", report.final_test_errors());
//! ```

pub mod active;
pub mod benchlib;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod learner;
pub mod metrics;
pub mod net;
pub mod nn;
pub mod obs;
pub mod rng;
pub mod serve;
pub mod simd;
pub mod runtime;
pub mod sim;
pub mod store;
pub mod svm;
pub mod theory;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::active::{
        margin::MarginSifter, PassiveSifter, QueryDecision, Sifter, SifterSpec,
    };
    pub use crate::coordinator::backend::{
        BackendChoice, SerialBackend, SiftBackend, SiftSession, ThreadedBackend,
    };
    pub use crate::coordinator::pipeline::{run_pipelined, run_pipelined_on};
    pub use crate::coordinator::sync::{
        run_sync, run_sync_on, SyncConfig, SyncReport, WallTimes,
    };
    pub use crate::coordinator::{
        run_sync_nn, run_sync_svm, NnExperimentConfig, SvmExperimentConfig,
    };
    pub use crate::data::{
        stream::{ExampleStream, StreamConfig},
        TestSet,
    };
    pub use crate::exec::{
        PoolConfig, PoolStats, ReplayConfig, ReplayExecutor, ScorerPool, WorkerPool,
        WorkerScorer,
    };
    pub use crate::learner::{Learner, LockedScorer, NativeScorer, SiftScorer};
    pub use crate::net::{
        config_fingerprint, run_distributed, serve_sift_node, InProcTransport, MlpDenseCodec,
        ModelCodec, NetStats, SvmDeltaCodec, TaskKind, Transport, UdsTransport,
    };
    pub use crate::obs::{Histogram, ObsReport, ShardedHistogram, SpanRecord};
    pub use crate::serve::{
        DaemonConfig, LearnSession, SessionCheckpoint, SessionConfig,
    };
    pub use crate::store::{CheckpointStore, FaultStore, FsStore, IoFaultPlan, Store};
    pub use crate::simd::ScoreScratch;
    pub use crate::metrics::{ErrorCurve, SpeedupTable};
    pub use crate::nn::{AdaGradMlp, MlpConfig};
    pub use crate::svm::{lasvm::LaSvm, LaSvmConfig, RbfKernel};
}
