//! Cluster timing simulation.
//!
//! The paper's §4 "Parallel simulation" paragraph defines the measurement
//! protocol we reproduce: split a global batch into k shards, run each
//! node's sift phase in turn, take the **largest** sift time across nodes
//! per round, add the model-update time and the initial warmstart time, and
//! ignore communication (batched, pipelined broadcasts are dominated by
//! sifting/updating). [`RoundClock`] implements exactly that.
//!
//! Beyond the paper, [`NodeProfile`] adds per-node speed factors (for the
//! asynchronous experiments E9 — stragglers are the motivation for
//! Algorithm 2) and [`CommModel`] an optional per-broadcast cost so the
//! "communication is negligible" assumption is itself testable.

use std::time::{Duration, Instant};

/// Heterogeneous node speeds: node i's work takes `factor[i] ×` as long.
#[derive(Debug, Clone)]
pub struct NodeProfile {
    factors: Vec<f64>,
}

impl NodeProfile {
    /// All nodes equally fast (the paper's setting).
    pub fn uniform(k: usize) -> Self {
        NodeProfile { factors: vec![1.0; k] }
    }

    /// One straggler running `slow ×` slower than the rest.
    pub fn with_straggler(k: usize, slow: f64) -> Self {
        assert!(k >= 1 && slow >= 1.0);
        let mut factors = vec![1.0; k];
        factors[k - 1] = slow;
        NodeProfile { factors }
    }

    /// Arbitrary factors.
    pub fn from_factors(factors: Vec<f64>) -> Self {
        assert!(!factors.is_empty());
        NodeProfile { factors }
    }

    pub fn k(&self) -> usize {
        self.factors.len()
    }

    pub fn factor(&self, node: usize) -> f64 {
        self.factors[node]
    }
}

/// Optional communication cost model for the ordered broadcast.
#[derive(Debug, Clone, Copy)]
pub struct CommModel {
    /// Fixed per-broadcast latency (seconds).
    pub latency: f64,
    /// Per-byte cost (seconds/byte); a 784-f32 example is ~3.1 KB.
    pub per_byte: f64,
    /// Broadcasts per round are pipelined: total = latency + per_byte * bytes
    /// (not latency * count).
    pub pipelined: bool,
}

impl CommModel {
    /// The paper's assumption: communication is free.
    pub fn free() -> Self {
        CommModel { latency: 0.0, per_byte: 0.0, pipelined: true }
    }

    /// Cost of broadcasting `count` examples of `bytes` bytes each.
    pub fn round_cost(&self, count: usize, bytes: usize) -> f64 {
        if count == 0 {
            return 0.0;
        }
        let payload = self.per_byte * (count * bytes) as f64;
        if self.pipelined {
            self.latency + payload
        } else {
            self.latency * count as f64 + payload
        }
    }
}

/// Accumulates simulated parallel wall-clock, round by round.
#[derive(Debug, Clone)]
pub struct RoundClock {
    profile: NodeProfile,
    comm: CommModel,
    /// Total simulated elapsed seconds.
    elapsed: f64,
    /// Per-phase accounting.
    pub sift_time: f64,
    pub update_time: f64,
    pub comm_time: f64,
    pub warmstart_time: f64,
    rounds: u64,
}

impl RoundClock {
    pub fn new(profile: NodeProfile, comm: CommModel) -> Self {
        RoundClock {
            profile,
            comm,
            elapsed: 0.0,
            sift_time: 0.0,
            update_time: 0.0,
            comm_time: 0.0,
            warmstart_time: 0.0,
            rounds: 0,
        }
    }

    pub fn k(&self) -> usize {
        self.profile.k()
    }

    /// Charge the warmstart (runs once, on one node).
    pub fn charge_warmstart(&mut self, seconds: f64) {
        self.warmstart_time += seconds;
        self.elapsed += seconds;
    }

    /// Charge one synchronous round: per-node sift durations (scaled by the
    /// node profile, max taken), the pooled update, and the broadcasts.
    pub fn charge_round(
        &mut self,
        node_sift_seconds: &[f64],
        update_seconds: f64,
        broadcast_count: usize,
        example_bytes: usize,
    ) {
        self.charge_round_inner(
            node_sift_seconds,
            update_seconds,
            broadcast_count,
            example_bytes,
            false,
        );
    }

    /// Charge one **pipelined** round: the sift phase and the update
    /// replay ran concurrently, so simulated time advances by the *max*
    /// of the two instead of their sum. Phase accounting still records
    /// both phases in full — for pipelined runs
    /// `sift + update + comm + warmstart` therefore exceeds `elapsed`,
    /// and the gap is exactly the modeled pipelining win.
    pub fn charge_round_overlapped(
        &mut self,
        node_sift_seconds: &[f64],
        update_seconds: f64,
        broadcast_count: usize,
        example_bytes: usize,
    ) {
        self.charge_round_inner(
            node_sift_seconds,
            update_seconds,
            broadcast_count,
            example_bytes,
            true,
        );
    }

    /// The shared round charge: profile-weighted max over node sift
    /// times, comm cost, per-phase accumulation. `overlapped` selects how
    /// sift and update combine into elapsed time (max vs sum) — the only
    /// difference between the strict and pipelined clocks, kept in one
    /// place so the two can never drift apart.
    fn charge_round_inner(
        &mut self,
        node_sift_seconds: &[f64],
        update_seconds: f64,
        broadcast_count: usize,
        example_bytes: usize,
        overlapped: bool,
    ) {
        assert_eq!(node_sift_seconds.len(), self.profile.k());
        let sift = node_sift_seconds
            .iter()
            .enumerate()
            .map(|(i, &s)| s * self.profile.factor(i))
            .fold(0.0f64, f64::max);
        let comm = self.comm.round_cost(broadcast_count, example_bytes);
        self.sift_time += sift;
        self.update_time += update_seconds;
        self.comm_time += comm;
        let round = if overlapped { sift.max(update_seconds) } else { sift + update_seconds };
        self.elapsed += round + comm;
        self.rounds += 1;
    }

    /// Charge update work that happens outside any sift round — the final
    /// flush of a bounded-staleness replay backlog. No round is counted.
    pub fn charge_update(&mut self, seconds: f64) {
        self.update_time += seconds;
        self.elapsed += seconds;
    }

    pub fn elapsed_seconds(&self) -> f64 {
        self.elapsed
    }

    pub fn rounds(&self) -> u64 {
        self.rounds
    }
}

/// Wall-clock stopwatch for measuring real phase durations.
#[derive(Debug)]
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn lap(&mut self) -> f64 {
        let now = Instant::now();
        let d = now.duration_since(self.0);
        self.0 = now;
        duration_secs(d)
    }
}

fn duration_secs(d: Duration) -> f64 {
    d.as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_takes_max_over_nodes() {
        let mut clock = RoundClock::new(NodeProfile::uniform(3), CommModel::free());
        clock.charge_round(&[1.0, 3.0, 2.0], 0.5, 10, 3136);
        assert!((clock.elapsed_seconds() - 3.5).abs() < 1e-12);
        assert_eq!(clock.rounds(), 1);
    }

    #[test]
    fn straggler_dominates() {
        let mut clock =
            RoundClock::new(NodeProfile::with_straggler(4, 10.0), CommModel::free());
        clock.charge_round(&[1.0, 1.0, 1.0, 1.0], 0.0, 0, 0);
        assert!((clock.elapsed_seconds() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn overlapped_round_takes_max_of_sift_and_update() {
        let mut clock = RoundClock::new(NodeProfile::uniform(2), CommModel::free());
        // Update longer than sift: the round costs the update time.
        clock.charge_round_overlapped(&[1.0, 2.0], 3.0, 5, 3136);
        assert!((clock.elapsed_seconds() - 3.0).abs() < 1e-12);
        // Sift longer than update: the round costs the (max-node) sift.
        clock.charge_round_overlapped(&[4.0, 1.0], 0.5, 5, 3136);
        assert!((clock.elapsed_seconds() - 7.0).abs() < 1e-12);
        assert_eq!(clock.rounds(), 2);
        // Phase accounting still records both phases in full.
        assert!((clock.sift_time - 6.0).abs() < 1e-12);
        assert!((clock.update_time - 3.5).abs() < 1e-12);
    }

    #[test]
    fn warmstart_accumulates() {
        let mut clock = RoundClock::new(NodeProfile::uniform(1), CommModel::free());
        clock.charge_warmstart(2.0);
        clock.charge_round(&[1.0], 1.0, 0, 0);
        assert!((clock.elapsed_seconds() - 4.0).abs() < 1e-12);
        assert!((clock.warmstart_time - 2.0).abs() < 1e-12);
    }

    #[test]
    fn comm_model_pipelined_vs_not() {
        let pipelined = CommModel { latency: 0.1, per_byte: 1e-6, pipelined: true };
        let serial = CommModel { latency: 0.1, per_byte: 1e-6, pipelined: false };
        let (n, b) = (100, 3136);
        assert!(pipelined.round_cost(n, b) < serial.round_cost(n, b));
        assert_eq!(pipelined.round_cost(0, b), 0.0);
        let expect = 0.1 + 1e-6 * (n * b) as f64;
        assert!((pipelined.round_cost(n, b) - expect).abs() < 1e-12);
    }

    #[test]
    fn phase_accounting_sums_to_elapsed() {
        let mut clock = RoundClock::new(
            NodeProfile::uniform(2),
            CommModel { latency: 0.01, per_byte: 0.0, pipelined: true },
        );
        clock.charge_warmstart(1.0);
        clock.charge_round(&[0.5, 0.25], 0.2, 5, 100);
        clock.charge_round(&[0.1, 0.3], 0.1, 2, 100);
        let sum =
            clock.warmstart_time + clock.sift_time + clock.update_time + clock.comm_time;
        assert!((sum - clock.elapsed_seconds()).abs() < 1e-12);
    }

    #[test]
    fn stopwatch_monotone() {
        let mut sw = Stopwatch::start();
        let a = sw.lap();
        let b = sw.lap();
        assert!(a >= 0.0 && b >= 0.0);
    }
}
