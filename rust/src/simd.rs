//! Vectorization-friendly inner loops and the tiled-kernel layer behind
//! the blocked batch-scoring engine.
//!
//! Two ideas live here:
//!
//! 1. **Lane accumulators.** Rust's default float semantics forbid
//!    reassociating `acc += d*d` across iterations, so naive reductions
//!    compile to scalar chains. Accumulating into a fixed-width lane array
//!    makes the reassociation explicit and lets LLVM map it onto SIMD
//!    registers (≈8x on AVX2 for the 784-dim loops). Measured before/after
//!    lives in EXPERIMENTS.md §Perf.
//! 2. **Row blocking.** The sift hot path scores whole shards against a
//!    frozen model, so the batch dimension is free parallel structure:
//!    [`gemm_nt`] keeps a block of [`BLOCK_ROWS`] example rows resident in
//!    cache and streams each weight/SV row across the block **once**,
//!    instead of re-streaming the full weight matrix (MLP: 100×784 ≈
//!    300 KB) or support set per example. Both learners build their
//!    `score_batch` override on these tiles; [`ScoreScratch`] supplies the
//!    reusable buffers so the hot path performs zero heap allocations.
//!
//! Bit-for-bit discipline: every tile entry is produced by the *same*
//! [`dot`] kernel regardless of block shape, so blocked results are
//! invariant to batch size and identical across backends. The equivalence
//! contract is enforced by `rust/tests/scoring_equivalence.rs`.

use std::cell::RefCell;

const LANES: usize = 8;

/// Example-block height of the tiled scoring kernels: this many input rows
/// stay cache-resident while weight/SV rows stream across them.
pub const BLOCK_ROWS: usize = 8;

/// Weight/SV-tile width of the blocked kernels: scratch tiles hold
/// `BLOCK_ROWS * BLOCK_COLS` values (small enough for L1).
pub const BLOCK_COLS: usize = 16;

/// Squared Euclidean distance ||a - b||^2.
#[inline]
pub fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; LANES];
    let ca = a.chunks_exact(LANES);
    let cb = b.chunks_exact(LANES);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for i in 0..LANES {
            let d = xa[i] - xb[i];
            acc[i] += d * d;
        }
    }
    let mut d2 = acc.iter().sum::<f32>();
    for (x, y) in ra.iter().zip(rb) {
        let d = x - y;
        d2 += d * d;
    }
    d2
}

/// Dot product a·b.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; LANES];
    let ca = a.chunks_exact(LANES);
    let cb = b.chunks_exact(LANES);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for i in 0..LANES {
            acc[i] += xa[i] * xb[i];
        }
    }
    acc.iter().sum::<f32>() + ra.iter().zip(rb).map(|(x, y)| x * y).sum::<f32>()
}

/// Squared Euclidean norm ||a||^2, lane-accumulated. Produces exactly the
/// bits of `dot(a, a)` (same accumulation pattern), so snapshot norms and
/// on-the-fly norms agree.
#[inline]
pub fn sqnorm(a: &[f32]) -> f32 {
    let ca = a.chunks_exact(LANES);
    let r = ca.remainder();
    let mut acc = [0.0f32; LANES];
    for xa in ca {
        for i in 0..LANES {
            acc[i] += xa[i] * xa[i];
        }
    }
    acc.iter().sum::<f32>() + r.iter().map(|x| x * x).sum::<f32>()
}

/// Fused a·b **and** ||a||^2 in one pass over `a`, for norm-trick kernels
/// (`||a - b||^2 = ||a||^2 + ||b||^2 - 2 a·b`) that stream a fresh row
/// exactly once. Each component is bit-identical to [`dot`] / [`sqnorm`]
/// run separately, so a fused caller stays on the equivalence contract.
///
/// The blocked engine itself does **not** call this: there every example
/// row meets many SV tiles, so norms are computed once per block
/// ([`sqnorm`]) and reused, which beats re-fusing them into any single
/// tile's dots. It belongs to single-pass consumers (streaming scorers,
/// one-shot kernel rows).
#[inline]
pub fn dot_sqnorm(a: &[f32], b: &[f32]) -> (f32, f32) {
    debug_assert_eq!(a.len(), b.len());
    let mut dacc = [0.0f32; LANES];
    let mut nacc = [0.0f32; LANES];
    let ca = a.chunks_exact(LANES);
    let cb = b.chunks_exact(LANES);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for i in 0..LANES {
            dacc[i] += xa[i] * xb[i];
            nacc[i] += xa[i] * xa[i];
        }
    }
    let d = dacc.iter().sum::<f32>() + ra.iter().zip(rb).map(|(x, y)| x * y).sum::<f32>();
    let n = nacc.iter().sum::<f32>() + ra.iter().map(|x| x * x).sum::<f32>();
    (d, n)
}

/// axpy: y += a * x (used by the blocked scorers).
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// Lane-accumulator micro-GEMM against a transposed weight matrix:
/// `out[i * n + j] = xs_i · ws_j` for `m` rows of `xs` and `n` rows of
/// `ws`, all of length `d` (`out` is m×n row-major).
///
/// Blocking: [`BLOCK_ROWS`] example rows stay cache-resident while each
/// `ws` row is streamed across the whole block, cutting weight-matrix
/// memory traffic by the block height — the main win when `ws` (the MLP's
/// `w1`, an SV tile) exceeds L1/L2. Every entry is produced by the same
/// [`dot`] kernel, so results are bit-identical for any `m`, which is what
/// keeps blocked scoring invariant to batch size.
pub fn gemm_nt(m: usize, n: usize, d: usize, xs: &[f32], ws: &[f32], out: &mut [f32]) {
    debug_assert_eq!(xs.len(), m * d);
    debug_assert_eq!(ws.len(), n * d);
    debug_assert_eq!(out.len(), m * n);
    let mut i0 = 0;
    while i0 < m {
        let ib = BLOCK_ROWS.min(m - i0);
        for j in 0..n {
            let w = &ws[j * d..(j + 1) * d];
            for i in i0..i0 + ib {
                out[i * n + j] = dot(&xs[i * d..(i + 1) * d], w);
            }
        }
        i0 += ib;
    }
}

/// Reusable buffers for the blocked scoring engine. The hot path borrows
/// slices that grow monotonically and are reused across calls, so
/// steady-state scoring performs **zero heap allocations**. Contents are
/// unspecified on entry — kernels must write before reading.
///
/// Ownership model: each execution-pool worker owns one (via
/// [`ScorerPool::native`](crate::exec::ScorerPool::native)), and every
/// other thread falls back to its private thread-local instance through
/// [`with_thread_scratch`]; no scratch is ever shared between threads.
#[derive(Debug, Default)]
pub struct ScoreScratch {
    a: Vec<f32>,
    b: Vec<f32>,
    c: Vec<f32>,
}

impl ScoreScratch {
    pub fn new() -> Self {
        ScoreScratch::default()
    }

    /// Borrow the primary buffer with at least `n` elements.
    pub fn primary(&mut self, n: usize) -> &mut [f32] {
        grow(&mut self.a, n)
    }

    /// Borrow two disjoint buffers (e.g. a kernel tile plus row norms).
    pub fn pair(&mut self, na: usize, nb: usize) -> (&mut [f32], &mut [f32]) {
        (grow(&mut self.a, na), grow(&mut self.b, nb))
    }

    /// Borrow three disjoint buffers — the fused minibatch update path
    /// needs a pre-activation tile plus two gradient accumulators
    /// (`AdaGradMlp::update_batch`).
    pub fn trio(
        &mut self,
        na: usize,
        nb: usize,
        nc: usize,
    ) -> (&mut [f32], &mut [f32], &mut [f32]) {
        (grow(&mut self.a, na), grow(&mut self.b, nb), grow(&mut self.c, nc))
    }
}

fn grow(v: &mut Vec<f32>, n: usize) -> &mut [f32] {
    if v.len() < n {
        v.resize(n, 0.0);
    }
    &mut v[..n]
}

thread_local! {
    static TLS_SCRATCH: RefCell<ScoreScratch> = RefCell::new(ScoreScratch::new());
}

/// Run `f` with this thread's private [`ScoreScratch`]. Pool workers are
/// distinct OS threads, so the threaded sift backends get one scratch per
/// worker with no locking and no allocation after warm-up. Not reentrant:
/// `f` must not call back into `with_thread_scratch` (the blocked scoring
/// overrides never do).
pub fn with_thread_scratch<R>(f: impl FnOnce(&mut ScoreScratch) -> R) -> R {
    TLS_SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn vecs(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (
            (0..n).map(|_| rng.next_f32() - 0.5).collect(),
            (0..n).map(|_| rng.next_f32() - 0.5).collect(),
        )
    }

    #[test]
    fn sqdist_matches_naive_all_lengths() {
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 100, 784] {
            let (a, b) = vecs(n, n as u64);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
            assert!(
                (sqdist(&a, &b) - naive).abs() <= 1e-5 * (1.0 + naive),
                "n={n}"
            );
        }
    }

    #[test]
    fn dot_matches_naive_all_lengths() {
        for n in [0usize, 1, 5, 8, 13, 784] {
            let (a, b) = vecs(n, 100 + n as u64);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() <= 1e-5 * (1.0 + naive.abs()), "n={n}");
        }
    }

    #[test]
    fn sqnorm_is_self_dot_bit_for_bit() {
        for n in [0usize, 1, 7, 8, 9, 33, 784] {
            let (a, _) = vecs(n, 900 + n as u64);
            assert_eq!(sqnorm(&a).to_bits(), dot(&a, &a).to_bits(), "n={n}");
        }
    }

    #[test]
    fn dot_sqnorm_matches_parts_bit_for_bit() {
        for n in [1usize, 5, 8, 13, 100, 784] {
            let (a, b) = vecs(n, 300 + n as u64);
            let (d, nn) = dot_sqnorm(&a, &b);
            assert_eq!(d.to_bits(), dot(&a, &b).to_bits(), "dot n={n}");
            assert_eq!(nn.to_bits(), sqnorm(&a).to_bits(), "norm n={n}");
        }
    }

    #[test]
    fn gemm_nt_matches_per_pair_dot_bit_for_bit() {
        // Block-shape invariance: every (m, n, d) — including remainders in
        // every dimension — must reproduce the per-pair dot exactly.
        const SHAPES: [(usize, usize, usize); 5] =
            [(1, 1, 3), (3, 5, 13), (8, 16, 8), (9, 17, 21), (33, 7, 784)];
        for &(m, n, d) in &SHAPES {
            let mut rng = Rng::new((m * 1000 + n * 10 + d) as u64);
            let xs: Vec<f32> = (0..m * d).map(|_| rng.next_f32() - 0.5).collect();
            let ws: Vec<f32> = (0..n * d).map(|_| rng.next_f32() - 0.5).collect();
            let mut out = vec![0.0f32; m * n];
            gemm_nt(m, n, d, &xs, &ws, &mut out);
            for i in 0..m {
                for j in 0..n {
                    let naive = dot(&xs[i * d..(i + 1) * d], &ws[j * d..(j + 1) * d]);
                    assert_eq!(
                        out[i * n + j].to_bits(),
                        naive.to_bits(),
                        "m={m} n={n} d={d} at ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn scratch_is_reused_not_reallocated() {
        let mut s = ScoreScratch::new();
        let p1 = s.primary(128).as_ptr();
        // A smaller (or equal) request must reuse the same allocation.
        let p2 = s.primary(64).as_ptr();
        assert_eq!(p1, p2);
        let (a, b) = s.pair(100, 50);
        a[0] = 1.0;
        b[0] = 2.0; // disjoint buffers
        assert_eq!(s.pair(100, 50).0[0], 1.0);
        assert_eq!(s.pair(100, 50).1[0], 2.0);
    }

    #[test]
    fn trio_buffers_are_disjoint_and_persistent() {
        let mut s = ScoreScratch::new();
        let (a, b, c) = s.trio(8, 4, 2);
        a[0] = 1.0;
        b[0] = 2.0;
        c[0] = 3.0;
        let (a2, b2, c2) = s.trio(8, 4, 2);
        assert_eq!((a2[0], b2[0], c2[0]), (1.0, 2.0, 3.0));
        // The trio shares the pair's first two allocations.
        assert_eq!(s.pair(8, 4).0[0], 1.0);
    }

    #[test]
    fn thread_scratch_is_usable() {
        let sum: f32 = with_thread_scratch(|s| {
            let buf = s.primary(16);
            buf.fill(0.5);
            buf.iter().sum()
        });
        assert_eq!(sum, 8.0);
    }

    #[test]
    fn axpy_matches_naive() {
        let (x, mut y) = vecs(33, 7);
        let mut y2 = y.clone();
        axpy(0.7, &x, &mut y);
        for (yi, xi) in y2.iter_mut().zip(&x) {
            *yi += 0.7 * xi;
        }
        for (a, b) in y.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
