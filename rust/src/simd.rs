//! Vectorization-friendly inner loops for the sift/update hot paths.
//!
//! Rust's default float semantics forbid reassociating `acc += d*d` across
//! iterations, so naive reductions compile to scalar chains. Accumulating
//! into a fixed-width lane array makes the reassociation explicit and lets
//! LLVM map it onto SIMD registers (≈8x on AVX2 for the 784-dim loops).
//! Measured before/after lives in EXPERIMENTS.md §Perf.

const LANES: usize = 8;

/// Squared Euclidean distance ||a - b||^2.
#[inline]
pub fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; LANES];
    let ca = a.chunks_exact(LANES);
    let cb = b.chunks_exact(LANES);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for i in 0..LANES {
            let d = xa[i] - xb[i];
            acc[i] += d * d;
        }
    }
    let mut d2 = acc.iter().sum::<f32>();
    for (x, y) in ra.iter().zip(rb) {
        let d = x - y;
        d2 += d * d;
    }
    d2
}

/// Dot product a·b.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; LANES];
    let ca = a.chunks_exact(LANES);
    let cb = b.chunks_exact(LANES);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for i in 0..LANES {
            acc[i] += xa[i] * xb[i];
        }
    }
    acc.iter().sum::<f32>() + ra.iter().zip(rb).map(|(x, y)| x * y).sum::<f32>()
}

/// axpy: y += a * x (used by the blocked scorers).
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn vecs(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (
            (0..n).map(|_| rng.next_f32() - 0.5).collect(),
            (0..n).map(|_| rng.next_f32() - 0.5).collect(),
        )
    }

    #[test]
    fn sqdist_matches_naive_all_lengths() {
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 100, 784] {
            let (a, b) = vecs(n, n as u64);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
            assert!(
                (sqdist(&a, &b) - naive).abs() <= 1e-5 * (1.0 + naive),
                "n={n}"
            );
        }
    }

    #[test]
    fn dot_matches_naive_all_lengths() {
        for n in [0usize, 1, 5, 8, 13, 784] {
            let (a, b) = vecs(n, 100 + n as u64);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() <= 1e-5 * (1.0 + naive.abs()), "n={n}");
        }
    }

    #[test]
    fn axpy_matches_naive() {
        let (x, mut y) = vecs(33, 7);
        let mut y2 = y.clone();
        axpy(0.7, &x, &mut y);
        for (yi, xi) in y2.iter_mut().zip(&x) {
            *yi += 0.7 * xi;
        }
        for (a, b) in y.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
