//! Algorithm 3: importance-weighted active learning (IWAL) with delayed
//! updates — the object of the paper's theory (Theorems 1–2).
//!
//! The learner sees a stream x_1, x_2, ...; at time t it computes the
//! empirical importance-weighted error of every hypothesis **over the
//! examples whose labels have already arrived** (those with index
//! ≤ t − τ(t), where τ is the delay process — e.g. τ ≡ B for batched
//! updating with batch size B). The query probability P_t is 1 when the
//! error gap G_t between the empirical best h_t and the best disagreeing
//! h'_t is below the sampling threshold, and otherwise the positive root
//! s ∈ (0, 1) of Eq (1):
//!
//! ```text
//! G_t = (c1/sqrt(s) - c1 + 1) sqrt(eps_t) + (c2/s - c2 + 1) eps_t,
//! eps_t = C0 log(n_t + 1) / n_t,   n_t = t - tau(t).
//! ```
//!
//! This implementation is exact for finite hypothesis classes (the theory
//! experiments use a grid of threshold classifiers, where ERM over the
//! applied prefix is computable in O(|H|) per step).

use crate::rng::Rng;
use std::collections::VecDeque;

/// A finite hypothesis class over inputs `X`.
pub trait Hypotheses<X> {
    fn count(&self) -> usize;
    /// Prediction of hypothesis `h` on `x`, in {-1, +1}.
    fn predict(&self, h: usize, x: &X) -> i8;
}

/// The constants of Beygelzimer et al. (2010): c1 = 5 + 2*sqrt(2), c2 = 5.
pub const C1: f64 = 7.828427124746190;
pub const C2: f64 = 5.0;

/// One example waiting for its (delayed) application to the error estimates.
#[derive(Debug, Clone)]
struct Pending<X> {
    x: X,
    y: i8,
    /// Query probability used at decision time.
    p: f64,
    /// Whether the label was actually queried.
    queried: bool,
}

/// Outcome of one IWAL step.
#[derive(Debug, Clone, Copy)]
pub struct IwalDecision {
    pub p: f64,
    pub queried: bool,
    /// n_t = number of examples applied when the decision was made.
    pub n_applied: u64,
    /// The error gap G_t (0 when fewer than 2 applied examples).
    pub gap: f64,
}

/// IWAL with delayed updates over a finite hypothesis class.
pub struct DelayedIwal<X, C: Hypotheses<X>> {
    class: C,
    /// C0 >= 2, the paper's O(log |H|/delta) tuning constant.
    pub c0: f64,
    /// Importance-weighted error *sums* per hypothesis over applied examples.
    err_sums: Vec<f64>,
    n_applied: u64,
    pending: VecDeque<Pending<X>>,
    t: u64,
    queries: u64,
    rng: Rng,
}

impl<X: Clone, C: Hypotheses<X>> DelayedIwal<X, C> {
    pub fn new(class: C, c0: f64, seed: u64) -> Self {
        assert!(c0 >= 2.0, "C0 must be >= 2 (got {c0})");
        let m = class.count();
        assert!(m >= 2, "need at least two hypotheses");
        DelayedIwal {
            class,
            c0,
            err_sums: vec![0.0; m],
            n_applied: 0,
            pending: VecDeque::new(),
            t: 0,
            queries: 0,
            rng: Rng::new(seed),
        }
    }

    pub fn t(&self) -> u64 {
        self.t
    }

    pub fn queries(&self) -> u64 {
        self.queries
    }

    pub fn n_applied(&self) -> u64 {
        self.n_applied
    }

    /// Empirical-best hypothesis over the applied prefix.
    pub fn best_hypothesis(&self) -> usize {
        self.err_sums
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap()
    }

    /// Empirical IW error of hypothesis `h` over the applied prefix.
    pub fn empirical_err(&self, h: usize) -> f64 {
        if self.n_applied == 0 {
            0.0
        } else {
            self.err_sums[h] / self.n_applied as f64
        }
    }

    /// Apply all pending examples with stream index ≤ `cutoff` (1-based).
    /// The caller's delay process decides when to call this: for a fixed
    /// batch delay B, call with cutoff = floor(t / B) * B; for the standard
    /// online setting call with cutoff = t after every step.
    pub fn apply_until(&mut self, cutoff: u64) {
        while self.n_applied < cutoff {
            let Some(ex) = self.pending.pop_front() else { break };
            self.n_applied += 1;
            if ex.queried {
                let w = 1.0 / ex.p;
                for h in 0..self.err_sums.len() {
                    if self.class.predict(h, &ex.x) != ex.y {
                        self.err_sums[h] += w;
                    }
                }
            }
        }
    }

    /// The sampling threshold sqrt(eps) + eps and eps itself for n applied.
    fn eps(&self) -> f64 {
        let n = self.n_applied as f64;
        self.c0 * (n + 1.0).ln() / n
    }

    /// Solve Eq (1) for s in (0, 1) by bisection (RHS is decreasing in s).
    /// Public for the property-test suite.
    pub fn solve_eq1(gap: f64, eps: f64) -> f64 {
        let rhs = |s: f64| -> f64 {
            (C1 / s.sqrt() - C1 + 1.0) * eps.sqrt() + (C2 / s - C2 + 1.0) * eps
        };
        let (mut lo, mut hi) = (1e-12, 1.0);
        for _ in 0..80 {
            let mid = 0.5 * (lo + hi);
            if rhs(mid) > gap {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }

    /// One IWAL step: decide the query probability for x_t, flip the coin,
    /// and enqueue the example for delayed application. `y` is the label
    /// that *would* be revealed if queried (the oracle's answer).
    pub fn step(&mut self, x: X, y: i8) -> IwalDecision {
        self.t += 1;
        let n = self.n_applied;
        let (p, gap) = if n == 0 {
            (1.0, 0.0)
        } else {
            // ERM and best disagreeing ERM on x.
            let mut best = f64::INFINITY;
            let mut best_h = 0;
            for (h, &s) in self.err_sums.iter().enumerate() {
                if s < best {
                    best = s;
                    best_h = h;
                }
            }
            let yhat = self.class.predict(best_h, &x);
            let mut best_dis = f64::INFINITY;
            for (h, &s) in self.err_sums.iter().enumerate() {
                if self.class.predict(h, &x) != yhat && s < best_dis {
                    best_dis = s;
                }
            }
            if !best_dis.is_finite() {
                // No hypothesis disagrees: the label is uninformative.
                (1.0, 0.0)
            } else {
                let gap = (best_dis - best) / n as f64;
                let eps = self.eps();
                if gap <= eps.sqrt() + eps {
                    (1.0, gap)
                } else {
                    (Self::solve_eq1(gap, eps).clamp(1e-12, 1.0), gap)
                }
            }
        };
        let queried = self.rng.coin(p);
        if queried {
            self.queries += 1;
        }
        self.pending.push_back(Pending { x, y, p, queried });
        IwalDecision { p, queried, n_applied: n, gap }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Threshold classifiers h_i(x) = sign(x - theta_i) on a grid.
    pub struct Thresholds {
        pub thetas: Vec<f64>,
    }

    impl Hypotheses<f64> for Thresholds {
        fn count(&self) -> usize {
            self.thetas.len()
        }
        fn predict(&self, h: usize, x: &f64) -> i8 {
            if *x >= self.thetas[h] {
                1
            } else {
                -1
            }
        }
    }

    fn grid(m: usize) -> Thresholds {
        Thresholds {
            thetas: (0..m).map(|i| i as f64 / (m - 1) as f64).collect(),
        }
    }

    fn run(noise: f64, delay: u64, t_max: u64, seed: u64) -> (DelayedIwal<f64, Thresholds>, u64) {
        let mut iwal = DelayedIwal::new(grid(41), 2.0, seed);
        let mut rng = Rng::new(seed ^ 0xDA7A);
        let theta_star = 0.3;
        for t in 1..=t_max {
            // Delay process: apply everything up to the last full batch.
            let cutoff = if delay <= 1 { t - 1 } else { ((t - 1) / delay) * delay };
            iwal.apply_until(cutoff);
            let x = rng.next_f64();
            let mut y: i8 = if x >= theta_star { 1 } else { -1 };
            if noise > 0.0 && rng.coin(noise) {
                y = -y;
            }
            iwal.step(x, y);
        }
        iwal.apply_until(t_max);
        let q = iwal.queries();
        (iwal, q)
    }

    #[test]
    fn finds_the_true_threshold_no_delay() {
        let (iwal, _) = run(0.0, 1, 3000, 1);
        let best = iwal.best_hypothesis();
        let theta = best as f64 / 40.0;
        assert!((theta - 0.3).abs() <= 0.05, "learned theta {theta}");
    }

    #[test]
    fn finds_the_true_threshold_with_large_delay() {
        // Theorem 1's point: a batch delay B does not derail learning.
        let (iwal, _) = run(0.0, 256, 3000, 2);
        let best = iwal.best_hypothesis();
        let theta = best as f64 / 40.0;
        assert!((theta - 0.3).abs() <= 0.05, "learned theta {theta} under delay");
    }

    #[test]
    fn queries_sublinear_in_separable_case() {
        let (_, q1) = run(0.0, 1, 2000, 3);
        let (_, q8) = run(0.0, 1, 16000, 3);
        // err(h*) = 0, so Thm 2 predicts ~sqrt(t log t) queries (~2.8x for
        // an 8x longer stream, constants aside): the query *rate* must drop
        // well below linear growth.
        let rate1 = q1 as f64 / 2000.0;
        let rate8 = q8 as f64 / 16000.0;
        assert!(
            rate8 < 0.75 * rate1,
            "query rate not decaying: {rate1:.3} @2k vs {rate8:.3} @16k ({q1}, {q8})"
        );
    }

    #[test]
    fn delay_increases_queries_only_mildly() {
        let (_, q_fast) = run(0.0, 1, 2000, 4);
        let (_, q_slow) = run(0.0, 128, 2000, 4);
        assert!(
            (q_slow as f64) < 4.0 * (q_fast as f64) + 200.0,
            "delayed queries blew up: {q_fast} vs {q_slow}"
        );
    }

    #[test]
    fn noisy_case_queries_scale_with_noise_floor() {
        let (_, q_clean) = run(0.0, 1, 3000, 5);
        let (_, q_noisy) = run(0.15, 1, 3000, 5);
        assert!(
            q_noisy > q_clean,
            "noise must increase label demand: {q_clean} vs {q_noisy}"
        );
    }

    #[test]
    fn eq1_root_properties() {
        // At the threshold gap the root is ~1; for larger gaps it shrinks.
        let eps: f64 = 0.01;
        let g_thresh = eps.sqrt() + eps;
        let s_at = DelayedIwal::<f64, Thresholds>::solve_eq1(g_thresh, eps);
        assert!(s_at > 0.9, "s at threshold ~1, got {s_at}");
        let s_big = DelayedIwal::<f64, Thresholds>::solve_eq1(10.0 * g_thresh, eps);
        assert!(s_big < s_at);
        let s_bigger = DelayedIwal::<f64, Thresholds>::solve_eq1(50.0 * g_thresh, eps);
        assert!(s_bigger < s_big);
        // Root actually solves the equation.
        let rhs = (C1 / s_big.sqrt() - C1 + 1.0) * eps.sqrt() + (C2 / s_big - C2 + 1.0) * eps;
        assert!((rhs - 10.0 * g_thresh).abs() < 1e-6);
    }

    #[test]
    fn importance_weights_keep_estimates_unbiased() {
        // The IW error of a fixed hypothesis must track its true error even
        // under aggressive sampling. True err of h at theta=0.5 with
        // theta*=0.3, uniform x: |0.5-0.3| = 0.2.
        let (iwal, _) = run(0.0, 1, 6000, 7);
        let h_half = 20; // theta = 0.5 on the 41-grid
        let est = iwal.empirical_err(h_half);
        assert!((est - 0.2).abs() < 0.08, "IW estimate {est} vs true 0.2");
    }

    #[test]
    fn first_step_queries_with_p1() {
        let mut iwal = DelayedIwal::new(grid(5), 2.0, 0);
        let d = iwal.step(0.4, 1);
        assert_eq!(d.p, 1.0);
        assert!(d.queried);
    }
}
