//! The paper's margin-based querying rule (§4, Eq 5):
//!
//! ```text
//! p = 2 / (1 + exp(eta * |f(x)| * sqrt(n)))
//! ```
//!
//! where `n` is the cumulative number of examples seen by the cluster at the
//! start of the current sift phase. The motivation: in low-noise problems
//! prediction uncertainty shrinks at ~1/sqrt(n), so the sampling region
//! around the boundary contracts at the same rate; `eta` modulates the
//! aggressiveness (paper: 0.01 sequential SVM, 0.1 parallel SVM, 0.0005 NN).

use super::{QueryDecision, Sifter};
use crate::rng::Rng;

/// Margin sifter implementing Eq (5).
#[derive(Debug, Clone)]
pub struct MarginSifter {
    pub eta: f64,
    rng: Rng,
}

impl MarginSifter {
    pub fn new(eta: f64, seed: u64) -> Self {
        assert!(eta >= 0.0);
        MarginSifter { eta, rng: Rng::new(seed) }
    }

    /// The query probability itself (deterministic part of the rule).
    #[inline]
    pub fn probability(&self, score: f32, n_seen: u64) -> f64 {
        let z = self.eta * score.abs() as f64 * (n_seen as f64).sqrt();
        2.0 / (1.0 + z.exp())
    }

    /// Raw coin-flip RNG state, for checkpointing a live sifter.
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Rebuild a sifter mid-sequence from a checkpointed
    /// [`MarginSifter::rng_state`].
    pub fn from_state(eta: f64, state: [u64; 4]) -> Self {
        assert!(eta >= 0.0);
        MarginSifter { eta, rng: Rng::from_state(state) }
    }
}

impl Sifter for MarginSifter {
    fn decide(&mut self, score: f32, n_seen: u64) -> QueryDecision {
        // Floor keeps importance weights 1/p finite in f32 even for
        // extremely confident scores (IWAL's "not-too-small" requirement).
        let p = self.probability(score, n_seen).clamp(1e-12, 1.0);
        QueryDecision { score, p, queried: self.rng.coin(p) }
    }

    fn name(&self) -> &'static str {
        "margin"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_margin_always_queried() {
        let mut s = MarginSifter::new(0.1, 0);
        for n in [0u64, 10, 10_000] {
            let d = s.decide(0.0, n);
            assert!(d.queried, "p(0-margin) must be 1");
            assert!((d.p - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn probability_matches_formula() {
        let s = MarginSifter::new(0.01, 0);
        let p = s.probability(2.0, 4000);
        let expect = 2.0 / (1.0 + (0.01 * 2.0 * (4000.0f64).sqrt()).exp());
        assert!((p - expect).abs() < 1e-12);
    }

    #[test]
    fn monotone_in_margin_and_n() {
        let s = MarginSifter::new(0.05, 0);
        assert!(s.probability(0.5, 100) > s.probability(1.0, 100));
        assert!(s.probability(0.5, 100) > s.probability(0.5, 10_000));
        assert!(s.probability(-0.5, 100) == s.probability(0.5, 100));
    }

    #[test]
    fn sampling_rate_decays_like_the_paper() {
        // With confident scores and growing n, the empirical query rate must
        // collapse toward a few percent — the regime the paper reports (~2%).
        let mut s = MarginSifter::new(0.1, 3);
        let mut queried = 0;
        let trials = 2000;
        for i in 0..trials {
            // scores away from the boundary, |f| ~ 1
            let score = if i % 2 == 0 { 1.0 } else { -1.2 };
            if s.decide(score, 1_000_000).queried {
                queried += 1;
            }
        }
        let rate = queried as f64 / trials as f64;
        assert!(rate < 0.05, "rate should collapse, got {rate}");
    }

    #[test]
    fn eta_zero_is_passive() {
        let mut s = MarginSifter::new(0.0, 1);
        for i in 0..50 {
            let d = s.decide(i as f32, 1000);
            assert!(d.queried);
            assert_eq!(d.p, 1.0);
        }
    }

    #[test]
    fn probability_never_zero() {
        let mut s = MarginSifter::new(10.0, 2);
        let d = s.decide(100.0, u64::MAX >> 16);
        assert!(d.p > 0.0, "importance weights must stay finite");
    }
}
