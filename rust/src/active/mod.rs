//! Active-learning machinery: the sifting strategies `A` of Algorithms 1–2.
//!
//! A [`Sifter`] looks at a margin score and decides — with a not-too-small
//! probability — whether the example should be labeled/used, returning the
//! probability so the updater can importance-weight it (the IWAL principle,
//! Beygelzimer et al. 2009). Implementations:
//!
//! * [`margin::MarginSifter`] — the paper's Eq (5) rule used in all §4
//!   experiments;
//! * [`PassiveSifter`] — p ≡ 1 (passive learning expressed as a degenerate
//!   active learner, the paper's baseline);
//! * [`FixedRateSifter`] — uniform subsampling at a constant rate (ablation
//!   baseline: same communication volume, no informativeness signal);
//! * [`iwal::DelayedIwal`] — Algorithm 3, the delayed IWAL scheme whose
//!   guarantees (Theorems 1–2) the theory experiments validate.
//!
//! For the synchronous coordinator, sifters are built **per node** from a
//! [`SifterSpec`]: every node gets its own sifter whose RNG is seeded from
//! (experiment seed, node id). Decisions therefore depend only on a node's
//! own shard and coin sequence — never on how node phases interleave —
//! which is the property that lets the threaded sift backend reproduce the
//! serial run bit for bit.

pub mod iwal;
pub mod margin;

use crate::rng::Rng;

/// Outcome of sifting one example.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QueryDecision {
    /// Margin score the decision was based on.
    pub score: f32,
    /// Probability with which the example was (would be) queried.
    pub p: f64,
    /// The coin flip's outcome.
    pub queried: bool,
}

impl QueryDecision {
    /// Importance weight 1/p for the updater (only meaningful if queried).
    pub fn weight(&self) -> f32 {
        (1.0 / self.p) as f32
    }
}

/// An example-selection strategy driven by margin scores.
pub trait Sifter {
    /// Decide on one example given its score and the cumulative number of
    /// examples seen by the cluster before this sift phase (the paper's n).
    fn decide(&mut self, score: f32, n_seen: u64) -> QueryDecision;

    /// Human-readable name for reports.
    fn name(&self) -> &'static str;
}

/// Passive learning as a degenerate sifter: query everything with p = 1.
#[derive(Debug, Default, Clone)]
pub struct PassiveSifter;

impl Sifter for PassiveSifter {
    fn decide(&mut self, score: f32, _n_seen: u64) -> QueryDecision {
        QueryDecision { score, p: 1.0, queried: true }
    }
    fn name(&self) -> &'static str {
        "passive"
    }
}

/// Uniform subsampling at a fixed rate — an ablation baseline that matches
/// the active learner's communication volume without its informativeness.
#[derive(Debug, Clone)]
pub struct FixedRateSifter {
    pub rate: f64,
    rng: Rng,
}

impl FixedRateSifter {
    pub fn new(rate: f64, seed: u64) -> Self {
        assert!(rate > 0.0 && rate <= 1.0);
        FixedRateSifter { rate, rng: Rng::new(seed) }
    }
}

impl Sifter for FixedRateSifter {
    fn decide(&mut self, score: f32, _n_seen: u64) -> QueryDecision {
        QueryDecision {
            score,
            p: self.rate,
            queried: self.rng.coin(self.rate),
        }
    }
    fn name(&self) -> &'static str {
        "fixed-rate"
    }
}

/// A recipe for building one sifter per node with deterministic,
/// node-disjoint randomness. `node == 0` reproduces the plain seed, so
/// sequential (k = 1) runs keep their historical coin sequences.
#[derive(Debug, Clone, PartialEq)]
pub enum SifterSpec {
    /// Query everything with p = 1 (passive learning).
    Passive,
    /// The paper's Eq-5 margin rule.
    Margin { eta: f64, seed: u64 },
    /// Uniform subsampling at a fixed rate (ablation baseline).
    FixedRate { rate: f64, seed: u64 },
}

impl SifterSpec {
    pub fn margin(eta: f64, seed: u64) -> Self {
        SifterSpec::Margin { eta, seed }
    }

    /// Name of the strategy this spec builds (matches [`Sifter::name`]).
    pub fn name(&self) -> &'static str {
        match self {
            SifterSpec::Passive => "passive",
            SifterSpec::Margin { .. } => "margin",
            SifterSpec::FixedRate { .. } => "fixed-rate",
        }
    }

    /// Whether the sift phase must compute margin scores at all (passive
    /// must not be charged for them).
    pub fn needs_scores(&self) -> bool {
        !matches!(self, SifterSpec::Passive)
    }

    /// Build node `node`'s sifter. The node seed is a golden-ratio salt of
    /// the experiment seed, so streams of coins never overlap across nodes.
    pub fn build(&self, node: usize) -> Box<dyn Sifter + Send> {
        let salt = (node as u64).wrapping_mul(0x9E3779B97F4A7C15);
        match *self {
            SifterSpec::Passive => Box::new(PassiveSifter),
            SifterSpec::Margin { eta, seed } => {
                Box::new(margin::MarginSifter::new(eta, seed ^ salt))
            }
            SifterSpec::FixedRate { rate, seed } => {
                Box::new(FixedRateSifter::new(rate, seed ^ salt))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::margin::MarginSifter;
    use super::*;

    #[test]
    fn spec_builds_node_deterministic_sifters() {
        let spec = SifterSpec::margin(0.1, 42);
        // Same node twice -> identical decision sequence.
        let mut a = spec.build(3);
        let mut b = spec.build(3);
        for i in 0..50 {
            assert_eq!(a.decide(0.3, 100 + i), b.decide(0.3, 100 + i));
        }
        // Different nodes -> decorrelated coin sequences: advance a node-3
        // and a node-4 sifter in lockstep and require their decision
        // sequences to differ somewhere (they'd be identical if build()
        // ignored the node salt).
        let mut n3 = spec.build(3);
        let mut n4 = spec.build(4);
        let diverged =
            (0..200u64).any(|i| n3.decide(0.4, i).queried != n4.decide(0.4, i).queried);
        assert!(diverged, "node coins should be independent");
        // Node 0 reproduces the raw seed (sequential compatibility).
        let mut n0 = spec.build(0);
        let mut raw = MarginSifter::new(0.1, 42);
        for i in 0..50 {
            assert_eq!(n0.decide(0.2, i * 7), raw.decide(0.2, i * 7));
        }
    }

    #[test]
    fn spec_names_and_score_needs() {
        assert_eq!(SifterSpec::Passive.name(), "passive");
        assert!(!SifterSpec::Passive.needs_scores());
        let m = SifterSpec::margin(0.01, 1);
        assert_eq!(m.name(), "margin");
        assert_eq!(m.name(), m.build(0).name());
        assert!(m.needs_scores());
        let f = SifterSpec::FixedRate { rate: 0.5, seed: 2 };
        assert_eq!(f.name(), f.build(1).name());
        assert!(f.needs_scores());
    }

    #[test]
    fn passive_always_queries_at_p1() {
        let mut s = PassiveSifter;
        for i in 0..10 {
            let d = s.decide(i as f32, i * 100);
            assert!(d.queried);
            assert_eq!(d.p, 1.0);
            assert_eq!(d.weight(), 1.0);
        }
    }

    #[test]
    fn fixed_rate_statistics() {
        let mut s = FixedRateSifter::new(0.25, 7);
        let hits = (0..4000).filter(|_| s.decide(0.0, 0).queried).count();
        assert!((hits as f64 / 4000.0 - 0.25).abs() < 0.03);
    }

    #[test]
    fn weight_is_inverse_probability() {
        let d = QueryDecision { score: 0.0, p: 0.1, queried: true };
        assert!((d.weight() - 10.0).abs() < 1e-6);
    }
}
