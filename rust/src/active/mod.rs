//! Active-learning machinery: the sifting strategies `A` of Algorithms 1–2.
//!
//! A [`Sifter`] looks at a margin score and decides — with a not-too-small
//! probability — whether the example should be labeled/used, returning the
//! probability so the updater can importance-weight it (the IWAL principle,
//! Beygelzimer et al. 2009). Implementations:
//!
//! * [`margin::MarginSifter`] — the paper's Eq (5) rule used in all §4
//!   experiments;
//! * [`PassiveSifter`] — p ≡ 1 (passive learning expressed as a degenerate
//!   active learner, the paper's baseline);
//! * [`FixedRateSifter`] — uniform subsampling at a constant rate (ablation
//!   baseline: same communication volume, no informativeness signal);
//! * [`iwal::DelayedIwal`] — Algorithm 3, the delayed IWAL scheme whose
//!   guarantees (Theorems 1–2) the theory experiments validate.

pub mod iwal;
pub mod margin;

use crate::rng::Rng;

/// Outcome of sifting one example.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QueryDecision {
    /// Margin score the decision was based on.
    pub score: f32,
    /// Probability with which the example was (would be) queried.
    pub p: f64,
    /// The coin flip's outcome.
    pub queried: bool,
}

impl QueryDecision {
    /// Importance weight 1/p for the updater (only meaningful if queried).
    pub fn weight(&self) -> f32 {
        (1.0 / self.p) as f32
    }
}

/// An example-selection strategy driven by margin scores.
pub trait Sifter {
    /// Decide on one example given its score and the cumulative number of
    /// examples seen by the cluster before this sift phase (the paper's n).
    fn decide(&mut self, score: f32, n_seen: u64) -> QueryDecision;

    /// Human-readable name for reports.
    fn name(&self) -> &'static str;
}

/// Passive learning as a degenerate sifter: query everything with p = 1.
#[derive(Debug, Default, Clone)]
pub struct PassiveSifter;

impl Sifter for PassiveSifter {
    fn decide(&mut self, score: f32, _n_seen: u64) -> QueryDecision {
        QueryDecision { score, p: 1.0, queried: true }
    }
    fn name(&self) -> &'static str {
        "passive"
    }
}

/// Uniform subsampling at a fixed rate — an ablation baseline that matches
/// the active learner's communication volume without its informativeness.
#[derive(Debug, Clone)]
pub struct FixedRateSifter {
    pub rate: f64,
    rng: Rng,
}

impl FixedRateSifter {
    pub fn new(rate: f64, seed: u64) -> Self {
        assert!(rate > 0.0 && rate <= 1.0);
        FixedRateSifter { rate, rng: Rng::new(seed) }
    }
}

impl Sifter for FixedRateSifter {
    fn decide(&mut self, score: f32, _n_seen: u64) -> QueryDecision {
        QueryDecision {
            score,
            p: self.rate,
            queried: self.rng.coin(self.rate),
        }
    }
    fn name(&self) -> &'static str {
        "fixed-rate"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passive_always_queries_at_p1() {
        let mut s = PassiveSifter;
        for i in 0..10 {
            let d = s.decide(i as f32, i * 100);
            assert!(d.queried);
            assert_eq!(d.p, 1.0);
            assert_eq!(d.weight(), 1.0);
        }
    }

    #[test]
    fn fixed_rate_statistics() {
        let mut s = FixedRateSifter::new(0.25, 7);
        let hits = (0..4000).filter(|_| s.decide(0.0, 0).queried).count();
        assert!((hits as f64 / 4000.0 - 0.25).abs() < 0.03);
    }

    #[test]
    fn weight_is_inverse_probability() {
        let d = QueryDecision { score: 0.0, p: 0.1, queried: true };
        assert!((d.weight() - 10.0).abs() < 1e-6);
    }
}
