//! Small, deterministic, dependency-free PRNG (splitmix64 seeding +
//! xoshiro256++), plus the handful of distributions the library needs.
//!
//! Determinism matters here: every node's data stream, every elastic
//! deformation, and every query coin-flip must be exactly reproducible from
//! a seed so that experiments (and the async/sync equivalence tests) are
//! replayable.

/// xoshiro256++ PRNG seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        // splitmix64 to fill the state, as recommended by the xoshiro authors.
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Self { s }
    }

    /// Derive an independent child generator (for per-node streams).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// The raw xoshiro256++ state, for checkpointing. Restoring via
    /// [`Rng::from_state`] resumes the exact sequence.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a checkpointed [`Rng::state`].
    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            return (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
        }
    }

    /// Bernoulli coin with success probability `p`.
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn fork_is_independent_and_deterministic() {
        let mut parent1 = Rng::new(7);
        let mut parent2 = Rng::new(7);
        let mut c1 = parent1.fork(3);
        let mut c2 = parent2.fork(3);
        for _ in 0..50 {
            assert_eq!(c1.next_u64(), c2.next_u64());
        }
        let mut other = Rng::new(7).fork(4);
        let same = (0..64).filter(|_| c1.next_u64() == other.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn state_roundtrip_resumes_the_exact_sequence() {
        let mut a = Rng::new(21);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn coin_frequency() {
        let mut r = Rng::new(13);
        let hits = (0..10_000).filter(|_| r.coin(0.3)).count();
        assert!((hits as f64 / 10_000.0 - 0.3).abs() < 0.02);
    }
}
